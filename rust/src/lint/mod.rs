//! `detlint` — a determinism & concurrency lint that statically enforces
//! the repo's bitwise-reproducibility contract.
//!
//! Every result this reproduction produces — characterize datasets,
//! BO/SA tunes, fault-injected degraded runs — is contractually
//! bit-identical across `ExecPool` widths and derivable from seeds
//! alone.  The differential suites (`tests/exec_parallel.rs`,
//! `tests/gp_incremental.rs`) enforce that *dynamically*; this pass
//! enforces it *statically*, so a stray `HashMap` iteration or ambient
//! clock read in a new code path fails CI instead of waiting for a pin
//! to happen to catch it.
//!
//! Like `mutate/scanner.rs` (whose masking infrastructure it shares via
//! [`crate::util::source`]), this is a line-based scanner, not a Rust
//! parser: rustfmt'd code plus comment/string masking make spaced-token
//! matching reliable, and anything the heuristics over-approximate is
//! suppressed *explicitly* with a reviewed annotation:
//!
//! ```text
//! // detlint: allow(<rule-id>) -- <mandatory reason>
//! ```
//!
//! either trailing on the flagged line or standing alone on the line
//! above it.  An allow without a reason (or naming an unknown rule) is
//! itself a fatal problem.  The rule catalog, per-rule rationale and
//! the allow workflow are documented in `LINTS.md`; `detlint
//! --self-check` (see [`selfcheck`]) plants one-or-more violations per
//! rule into scratch copies of real files and demands each is flagged
//! at the expected file/rule, pinning the lint itself against rot.
//!
//! Scanning stops at the first top-level `#[cfg(test)]` in each file —
//! tests are oracles and may freely use wall-clocks, hash iteration and
//! raw threads.

pub mod report;
pub mod rules;
pub mod selfcheck;

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// The determinism rule catalog.  Ids are stable: they appear in allow
/// annotations, `detlint.json`, CI asserts and LINTS.md.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// R1: no iteration over `HashMap`/`HashSet` — iteration order is
    /// nondeterministic per process.  Declarations and point lookups
    /// (`get`, `contains_key`, `insert`, `entry`) stay legal; anything
    /// order-bearing must use `BTreeMap`/`BTreeSet` or sort first.
    HashIter,
    /// R2: no `Instant::now`/`SystemTime` influencing result values.
    /// Elapsed-time *reporting* (`elapsed_s`, `tuning_time_s`) and TTL
    /// bookkeeping are legitimate but must carry an allow annotation so
    /// every wall-clock read in the tree is a reviewed one.
    WallClock,
    /// R3: no RNG construction outside the seeded `splitmix64`-derived
    /// stream discipline of `util/rng.rs` — no thread-local or OS
    /// entropy (`RandomState`, `thread_rng`, `from_entropy`, …).
    AmbientRng,
    /// R4: no `thread::spawn`/`scope`/`Builder` outside `exec/` (the
    /// `ExecPool`/`JobRunner` home — its fixed-block sharding is what
    /// makes width-invariance provable) and `mutate/` (build-runner
    /// tooling, not a result path).
    ThreadOutsideExec,
    /// R5: no float reductions chained onto a concurrent fan-out
    /// (`par_map(..).iter().sum()` -style) and no shared float
    /// accumulators (`Mutex<f64>`) — reductions must run over the
    /// index-ordered results via the fixed-order helpers in
    /// `util/stats.rs`/`exec`.
    UnorderedFloatReduce,
    /// R6: no lock held across an I/O or blocking call in `server/`
    /// (the jobs/persist mutexes serve request threads; file writes
    /// under them turn a slow disk into a stalled API).
    LockAcrossIo,
}

impl Rule {
    pub const ALL: [Rule; 6] = [
        Rule::HashIter,
        Rule::WallClock,
        Rule::AmbientRng,
        Rule::ThreadOutsideExec,
        Rule::UnorderedFloatReduce,
        Rule::LockAcrossIo,
    ];

    pub fn id(self) -> &'static str {
        match self {
            Rule::HashIter => "hash-iter",
            Rule::WallClock => "wall-clock",
            Rule::AmbientRng => "ambient-rng",
            Rule::ThreadOutsideExec => "thread-outside-exec",
            Rule::UnorderedFloatReduce => "unordered-float-reduce",
            Rule::LockAcrossIo => "lock-across-io",
        }
    }

    pub fn parse(s: &str) -> Option<Rule> {
        Rule::ALL.iter().copied().find(|r| r.id() == s)
    }

    /// One-line invariant statement for reports.
    pub fn invariant(self) -> &'static str {
        match self {
            Rule::HashIter => "no HashMap/HashSet iteration (order nondeterministic)",
            Rule::WallClock => "no Instant/SystemTime influencing results",
            Rule::AmbientRng => "no RNG outside the seeded util/rng streams",
            Rule::ThreadOutsideExec => "no raw threads outside exec/ and mutate/",
            Rule::UnorderedFloatReduce => "no float reduce over concurrent fan-out",
            Rule::LockAcrossIo => "no lock held across blocking I/O in server/",
        }
    }

    /// Path scope: which repo-relative files the rule applies to.  The
    /// exemptions are the rule definitions themselves, not allows:
    /// `exec/` IS the approved thread home, `mutate/` is offline build
    /// tooling whose job is measuring real wall-clock timeouts, and
    /// `util/stats.rs`/`exec/` hold the approved fixed-order reducers.
    pub fn applies_to(self, file: &str) -> bool {
        match self {
            Rule::HashIter | Rule::AmbientRng => true,
            Rule::WallClock => !file.contains("/mutate/"),
            Rule::ThreadOutsideExec => {
                !file.contains("/exec/") && !file.contains("/mutate/")
            }
            Rule::UnorderedFloatReduce => {
                !file.contains("/exec/") && !file.ends_with("util/stats.rs")
            }
            Rule::LockAcrossIo => file.contains("/server/"),
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// An unsuppressed violation — any one of these fails the run.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub excerpt: String,
}

/// A violation suppressed by a well-formed allow annotation.
#[derive(Clone, Debug, PartialEq)]
pub struct AllowedFinding {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub reason: String,
    pub excerpt: String,
}

/// An allow annotation that matched no finding (reported, non-fatal:
/// detector refinements must not turn stale comments into red CI).
#[derive(Clone, Debug, PartialEq)]
pub struct StaleAllow {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub reason: String,
}

/// A malformed annotation (unknown rule, missing reason) — fatal.
#[derive(Clone, Debug, PartialEq)]
pub struct Problem {
    pub file: String,
    pub line: usize,
    pub message: String,
}

/// Outcome of scanning one file — see [`rules::scan_source`].
#[derive(Clone, Debug, Default)]
pub struct FileScan {
    pub findings: Vec<Finding>,
    pub allows: Vec<AllowedFinding>,
    pub stale_allows: Vec<StaleAllow>,
    pub problems: Vec<Problem>,
}

/// Whole-tree lint result.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub allows: Vec<AllowedFinding>,
    pub stale_allows: Vec<StaleAllow>,
    pub problems: Vec<Problem>,
}

impl LintReport {
    /// The CI gate: no unsuppressed violations, no malformed allows.
    pub fn clean(&self) -> bool {
        self.findings.is_empty() && self.problems.is_empty()
    }
}

/// Every `.rs` file under `dir`, recursively, in sorted (deterministic)
/// path order.
pub fn collect_rs_files(dir: &Path) -> Result<Vec<PathBuf>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("reading {}", dir.display()))?
        {
            let path = entry?.path();
            if path.is_dir() {
                walk(&path, out)?;
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(dir, &mut out)?;
    out.sort();
    Ok(out)
}

/// Sweep all of `rust/src/` under the repo `root`.
pub fn lint_root(root: &Path) -> Result<LintReport> {
    let src = root.join("rust").join("src");
    let files = collect_rs_files(&src)?;
    let mut rep = LintReport { files_scanned: files.len(), ..Default::default() };
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let scan = rules::scan_source(&rel, &text);
        rep.findings.extend(scan.findings);
        rep.allows.extend(scan.allows);
        rep.stale_allows.extend(scan.stale_allows);
        rep.problems.extend(scan.problems);
    }
    Ok(rep)
}
