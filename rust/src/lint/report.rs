//! Machine- and human-readable renderings of a [`LintReport`]:
//! schema-stable `detlint.json` (schema id `detlint/v1` — CI asserts on
//! it with jq) and a markdown summary table.

use crate::util::Json;

use super::{LintReport, Rule};

/// Render the report as the `detlint/v1` JSON document.  Object keys
/// are sorted by `Json::Obj` (BTreeMap) and every array here is built
/// in deterministic order (rules in catalog order, findings in sorted
/// file/line order), so the byte output is stable across runs.
pub fn to_json(rep: &LintReport) -> Json {
    let rules = Rule::ALL
        .iter()
        .map(|&r| {
            Json::obj(vec![
                ("id", Json::str(r.id())),
                ("invariant", Json::str(r.invariant())),
                (
                    "violations",
                    Json::num(rep.findings.iter().filter(|f| f.rule == r).count() as f64),
                ),
                (
                    "allows",
                    Json::num(rep.allows.iter().filter(|a| a.rule == r).count() as f64),
                ),
            ])
        })
        .collect();

    let violations = rep
        .findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("file", Json::str(&f.file)),
                ("line", Json::num(f.line as f64)),
                ("rule", Json::str(f.rule.id())),
                ("excerpt", Json::str(&f.excerpt)),
            ])
        })
        .collect();

    let allows = rep
        .allows
        .iter()
        .map(|a| {
            Json::obj(vec![
                ("file", Json::str(&a.file)),
                ("line", Json::num(a.line as f64)),
                ("rule", Json::str(a.rule.id())),
                ("reason", Json::str(&a.reason)),
                ("excerpt", Json::str(&a.excerpt)),
            ])
        })
        .collect();

    let stale = rep
        .stale_allows
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("file", Json::str(&s.file)),
                ("line", Json::num(s.line as f64)),
                ("rule", Json::str(s.rule.id())),
                ("reason", Json::str(&s.reason)),
            ])
        })
        .collect();

    let problems = rep
        .problems
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("file", Json::str(&p.file)),
                ("line", Json::num(p.line as f64)),
                ("message", Json::str(&p.message)),
            ])
        })
        .collect();

    Json::obj(vec![
        ("schema", Json::str("detlint/v1")),
        ("mode", Json::str("sweep")),
        ("files_scanned", Json::num(rep.files_scanned as f64)),
        ("clean", Json::Bool(rep.clean())),
        ("rules", Json::Arr(rules)),
        ("violations", Json::Arr(violations)),
        ("allows", Json::Arr(allows)),
        ("stale_allows", Json::Arr(stale)),
        ("problems", Json::Arr(problems)),
    ])
}

/// Markdown summary: verdict line, per-rule counts, every violation,
/// and the full allow ledger (each with its mandatory reason) so a
/// reviewer sees every sanctioned exception in one table.
pub fn summary_markdown(rep: &LintReport) -> String {
    let mut md = String::new();
    md.push_str("## detlint — determinism & concurrency lint\n\n");
    md.push_str(&format!(
        "Files scanned: {} · violations: {} · allows: {} · problems: {} → **{}**\n\n",
        rep.files_scanned,
        rep.findings.len(),
        rep.allows.len(),
        rep.problems.len(),
        if rep.clean() { "CLEAN" } else { "DIRTY" },
    ));

    md.push_str("| rule | invariant | violations | allows |\n");
    md.push_str("|---|---|---:|---:|\n");
    for &r in &Rule::ALL {
        let v = rep.findings.iter().filter(|f| f.rule == r).count();
        let a = rep.allows.iter().filter(|x| x.rule == r).count();
        md.push_str(&format!("| `{}` | {} | {v} | {a} |\n", r.id(), r.invariant()));
    }

    if !rep.findings.is_empty() {
        md.push_str("\n### Violations\n\n| file:line | rule | excerpt |\n|---|---|---|\n");
        for f in &rep.findings {
            md.push_str(&format!(
                "| `{}:{}` | `{}` | `{}` |\n",
                f.file,
                f.line,
                f.rule.id(),
                cell(&f.excerpt),
            ));
        }
    }

    if !rep.problems.is_empty() {
        md.push_str("\n### Problems (malformed annotations — fatal)\n\n");
        for p in &rep.problems {
            md.push_str(&format!("- `{}:{}` — {}\n", p.file, p.line, p.message));
        }
    }

    if !rep.allows.is_empty() {
        md.push_str("\n### Allow ledger\n\n| file:line | rule | reason |\n|---|---|---|\n");
        for a in &rep.allows {
            md.push_str(&format!(
                "| `{}:{}` | `{}` | {} |\n",
                a.file,
                a.line,
                a.rule.id(),
                cell(&a.reason),
            ));
        }
    }

    if !rep.stale_allows.is_empty() {
        md.push_str("\n### Stale allows (matched nothing — consider removing)\n\n");
        for s in &rep.stale_allows {
            md.push_str(&format!(
                "- `{}:{}` — allow({}) -- {}\n",
                s.file,
                s.line,
                s.rule.id(),
                s.reason,
            ));
        }
    }

    md
}

/// Escape a string for a one-line markdown table cell.
fn cell(s: &str) -> String {
    s.replace('|', "\\|").replace('\n', " ")
}
