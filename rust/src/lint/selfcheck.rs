//! `detlint --self-check` — lint-the-linter.
//!
//! A lint gate that silently stops matching is worse than no gate: the
//! contract looks enforced and isn't.  So the self-check patches known
//! violations ("plants") into in-memory copies of *real* repo files —
//! at least one per rule, plus negative controls (exempt paths, legal
//! point lookups, a reasoned allow) and one malformed annotation — then
//! scans the patched copies and demands every plant is reported at the
//! expected file, rule and line.  Nothing is written to disk.
//!
//! Plants are anchored by a substring of an existing source line, not a
//! line number, so ordinary edits don't break them; if an anchor
//! disappears entirely the plant fails loudly ("plant rot") instead of
//! silently skipping, and the anchor must be re-pointed.

use std::path::Path;

use anyhow::{Context, Result};

use super::{rules, Rule};

/// What the scanner must say about a plant's inserted lines.
enum Expect {
    /// An unsuppressed finding of this rule.
    Violation(Rule),
    /// An [`super::AllowedFinding`] of this rule, and no finding.
    Suppressed(Rule),
    /// A malformed-annotation problem.
    Problem,
    /// Nothing at all (negative control: exempt path or legal usage).
    Clean,
}

struct Plant {
    label: &'static str,
    /// Repo-relative file the plant is patched into.
    file: &'static str,
    /// Substring of an existing line; planted lines go right after it.
    anchor: &'static str,
    lines: &'static [&'static str],
    expect: Expect,
}

/// One plant per rule at minimum, plus negative controls.  Anchors are
/// chosen on load-bearing lines that the rule's real-world story lives
/// next to (the BO timer, the persist write guard, the fan-out calls).
const PLANTS: &[Plant] = &[
    Plant {
        label: "hash-iter: map iteration in tuner/bo.rs",
        file: "rust/src/tuner/bo.rs",
        anchor: "let t0 = Instant::now();",
        lines: &[
            "let planted: std::collections::HashMap<u64, f64> = std::collections::HashMap::new();",
            "for (k, v) in planted.iter() { let _ = (k, v); }",
        ],
        expect: Expect::Violation(Rule::HashIter),
    },
    Plant {
        label: "hash-iter: point lookups stay legal (negative control)",
        file: "rust/src/flags/catalog.rs",
        anchor: "pub fn flag_by_name(name: &str)",
        lines: &[
            "let planted_m: std::collections::HashMap<u64, f64> = std::collections::HashMap::new();",
            "let planted_v = planted_m.get(&1).copied();",
        ],
        expect: Expect::Clean,
    },
    Plant {
        label: "wall-clock: Instant::now in native/gp.rs",
        file: "rust/src/native/gp.rs",
        anchor: "pool.par_chunks(xc, EI_BLOCK",
        lines: &["let planted_t0 = std::time::Instant::now();"],
        expect: Expect::Violation(Rule::WallClock),
    },
    Plant {
        label: "wall-clock: SystemTime in server/jobs.rs",
        file: "rust/src/server/jobs.rs",
        anchor: "fn evict_expired(&self) {",
        lines: &["let planted_wall = std::time::SystemTime::now();"],
        expect: Expect::Violation(Rule::WallClock),
    },
    Plant {
        label: "ambient-rng: RandomState in tuner/sa.rs",
        file: "rust/src/tuner/sa.rs",
        anchor: "let t0 = Instant::now();",
        lines: &["let planted_rs = std::collections::hash_map::RandomState::new();"],
        expect: Expect::Violation(Rule::AmbientRng),
    },
    Plant {
        label: "thread-outside-exec: spawn in pipeline/mod.rs",
        file: "rust/src/pipeline/mod.rs",
        anchor: "let vals = pool.par_run(repeats.max(1), |i| {",
        lines: &["std::thread::spawn(|| {});"],
        expect: Expect::Violation(Rule::ThreadOutsideExec),
    },
    Plant {
        label: "thread-outside-exec: exec/ is exempt (negative control)",
        file: "rust/src/exec/mod.rs",
        anchor: "pub fn set_global_threads(threads: usize)",
        lines: &["std::thread::spawn(|| {});"],
        expect: Expect::Clean,
    },
    Plant {
        label: "unordered-float-reduce: sum over fan-out in datagen/mod.rs",
        file: "rust/src/datagen/mod.rs",
        anchor: "let runs: Vec<RunOutcome> = pool.par_map(cfgs, |i, cfg| {",
        lines: &["let planted_sum: f64 = pool.par_run(4, |i| i as f64).iter().sum();"],
        expect: Expect::Violation(Rule::UnorderedFloatReduce),
    },
    Plant {
        label: "unordered-float-reduce: Mutex<f64> accumulator in sparksim/runner.rs",
        file: "rust/src/sparksim/runner.rs",
        anchor: "let results = pool.par_map(&erngs, |_, erng| {",
        lines: &["let planted_acc: std::sync::Mutex<f64> = std::sync::Mutex::new(0.0);"],
        expect: Expect::Violation(Rule::UnorderedFloatReduce),
    },
    Plant {
        label: "lock-across-io: file write under persist_lock in server/api.rs",
        file: "rust/src/server/api.rs",
        anchor: "let _write_guard = self.persist_lock.lock().unwrap();",
        lines: &["std::fs::write(\"/tmp/detlint_planted\", \"x\").ok();"],
        expect: Expect::Violation(Rule::LockAcrossIo),
    },
    Plant {
        label: "allow without reason is a fatal problem",
        file: "rust/src/report/mod.rs",
        anchor: "pub fn save_result(dir: impl AsRef<Path>",
        lines: &["let planted_p = std::time::Instant::now(); // detlint: allow(wall-clock)"],
        expect: Expect::Problem,
    },
    Plant {
        label: "allow with reason suppresses (negative control)",
        file: "rust/src/featsel/mod.rs",
        anchor: "let sum: f64 = inv.iter().sum();",
        lines: &[
            "let planted_ok = std::time::Instant::now(); // detlint: allow(wall-clock) -- planted negative control: annotated with a reason",
        ],
        expect: Expect::Suppressed(Rule::WallClock),
    },
];

/// Outcome of one plant.
pub struct PlantResult {
    pub label: &'static str,
    pub file: &'static str,
    pub ok: bool,
    pub detail: String,
}

pub fn all_ok(results: &[PlantResult]) -> bool {
    results.iter().all(|r| r.ok)
}

/// Render the per-plant outcome table.
pub fn summary_markdown(results: &[PlantResult]) -> String {
    let passed = results.iter().filter(|r| r.ok).count();
    let mut md = String::new();
    md.push_str("## detlint --self-check\n\n");
    md.push_str(&format!(
        "{passed}/{} plants verified → **{}**\n\n| plant | file | outcome |\n|---|---|---|\n",
        results.len(),
        if passed == results.len() { "OK" } else { "FAILED" },
    ));
    for r in results {
        md.push_str(&format!(
            "| {} | `{}` | {} |\n",
            r.label,
            r.file,
            if r.ok { "ok".to_string() } else { format!("**FAIL** — {}", r.detail) },
        ));
    }
    md
}

/// Patch and scan every plant against the tree under `root`.
pub fn run(root: &Path) -> Result<Vec<PlantResult>> {
    PLANTS.iter().map(|p| check_plant(root, p)).collect()
}

fn check_plant(root: &Path, plant: &Plant) -> Result<PlantResult> {
    let path = root.join(plant.file);
    let src = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;

    let fail = |detail: String| PlantResult {
        label: plant.label,
        file: plant.file,
        ok: false,
        detail,
    };

    let lines: Vec<&str> = src.lines().collect();
    let Some(anchor_idx) = lines.iter().position(|l| l.contains(plant.anchor)) else {
        return Ok(fail(format!(
            "plant rot: anchor `{}` no longer exists — re-point the plant",
            plant.anchor
        )));
    };

    // Splice the planted lines in after the anchor, matching its indent
    // (one level deeper when the anchor opens a block).
    let anchor_line = lines[anchor_idx];
    let mut indent: String =
        anchor_line.chars().take_while(|c| c.is_whitespace()).collect();
    if anchor_line.trim_end().ends_with('{') {
        indent.push_str("    ");
    }
    let mut patched: Vec<String> = lines.iter().map(|l| l.to_string()).collect();
    for (k, planted) in plant.lines.iter().enumerate() {
        patched.insert(anchor_idx + 1 + k, format!("{indent}{planted}"));
    }
    let patched_src = patched.join("\n");

    // 1-based line range the planted lines occupy in the patched copy.
    let lo = anchor_idx + 2;
    let hi = anchor_idx + 1 + plant.lines.len();
    let in_range = |n: usize| n >= lo && n <= hi;

    let scan = rules::scan_source(plant.file, &patched_src);
    let hit_findings: Vec<_> = scan.findings.iter().filter(|f| in_range(f.line)).collect();
    let hit_allows: Vec<_> = scan.allows.iter().filter(|a| in_range(a.line)).collect();
    let hit_problems: Vec<_> = scan.problems.iter().filter(|p| in_range(p.line)).collect();

    let detail = match &plant.expect {
        Expect::Violation(rule) => {
            if hit_findings.iter().any(|f| f.rule == *rule) {
                None
            } else {
                Some(format!(
                    "expected a {} violation in lines {lo}..={hi}, scanner reported {:?}",
                    rule.id(),
                    hit_findings.iter().map(|f| (f.line, f.rule.id())).collect::<Vec<_>>(),
                ))
            }
        }
        Expect::Suppressed(rule) => {
            if !hit_allows.iter().any(|a| a.rule == *rule) {
                Some(format!("expected an allowed {} finding in lines {lo}..={hi}", rule.id()))
            } else if !hit_findings.is_empty() {
                Some("allow failed to suppress: finding still reported".to_string())
            } else {
                None
            }
        }
        Expect::Problem => {
            if hit_problems.is_empty() {
                Some(format!("expected a malformed-annotation problem in lines {lo}..={hi}"))
            } else {
                None
            }
        }
        Expect::Clean => {
            if hit_findings.is_empty() && hit_problems.is_empty() {
                None
            } else {
                Some(format!(
                    "expected no report, got {:?}",
                    hit_findings.iter().map(|f| (f.line, f.rule.id())).collect::<Vec<_>>(),
                ))
            }
        }
    };

    Ok(match detail {
        None => PlantResult { label: plant.label, file: plant.file, ok: true, detail: String::new() },
        Some(d) => fail(d),
    })
}
