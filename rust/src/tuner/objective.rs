//! Objective functions for phase 3: the real benchmark (eq. 1's Q) and the
//! LR-predictor surrogate used by RBO.

use crate::exec::{self, ExecPool};
use crate::flags::FlagConfig;
use crate::sparksim::SparkRunner;
use crate::util::stats::{Standardizer, TargetScaler};
use crate::Metric;

/// Minimization objective over flag configurations.
pub trait Objective {
    /// Evaluate one configuration.
    fn eval(&mut self, cfg: &FlagConfig) -> f64;

    /// Benchmark executions consumed so far.
    fn evals(&self) -> usize;

    /// Simulated benchmark wall time consumed so far (seconds).
    fn sim_time_s(&self) -> f64;
}

/// The real objective: run the benchmark on the simulated cluster.
pub struct SimObjective<'a> {
    pub runner: &'a SparkRunner,
    pub metric: Metric,
    seed: u64,
    count: usize,
    sim_time_s: f64,
    /// Pool for the per-executor fan-out inside each run.  The global pool
    /// when this objective is the only thing running (a lone tuning job);
    /// serial when the caller already fans several tuners out in parallel
    /// (`run_pipeline`'s algorithm sweep) — results are identical either
    /// way, only thread scheduling differs.
    pool: ExecPool,
}

impl<'a> SimObjective<'a> {
    pub fn new(runner: &'a SparkRunner, metric: Metric, seed: u64) -> Self {
        Self::new_on(runner, metric, seed, *exec::global())
    }

    /// `new` with an explicit per-run executor fan-out pool.
    pub fn new_on(runner: &'a SparkRunner, metric: Metric, seed: u64, pool: ExecPool) -> Self {
        SimObjective { runner, metric, seed, count: 0, sim_time_s: 0.0, pool }
    }
}

impl Objective for SimObjective<'_> {
    fn eval(&mut self, cfg: &FlagConfig) -> f64 {
        self.count += 1;
        let m = self.runner.run_on(&self.pool, cfg, self.seed.wrapping_add(self.count as u64));
        self.sim_time_s += m.wall_clock_s;
        let mut v = self.metric.of(&m);
        if m.timed_out && self.metric == Metric::HeapUsage {
            v += 50.0; // a crashing config must not win the memory race
        }
        v
    }

    fn evals(&self) -> usize {
        self.count
    }

    fn sim_time_s(&self) -> f64 {
        self.sim_time_s
    }
}

/// Objective for the parallel-run scenario (paper §V-E / Fig 6): the tuned
/// benchmark runs concurrently with a second job (at its default flags) on
/// the shared cluster, and the tuned job's metric is returned.
pub struct ParallelSimObjective {
    pub cluster: crate::sparksim::ClusterSpec,
    pub target: (crate::Benchmark, crate::sparksim::ExecutorSpec),
    pub other: (crate::Benchmark, FlagConfig, crate::sparksim::ExecutorSpec),
    pub metric: Metric,
    seed: u64,
    count: usize,
    sim_time_s: f64,
}

impl ParallelSimObjective {
    pub fn new(
        cluster: crate::sparksim::ClusterSpec,
        target: (crate::Benchmark, crate::sparksim::ExecutorSpec),
        other: (crate::Benchmark, FlagConfig, crate::sparksim::ExecutorSpec),
        metric: Metric,
        seed: u64,
    ) -> Self {
        ParallelSimObjective { cluster, target, other, metric, seed, count: 0, sim_time_s: 0.0 }
    }

    /// Evaluate a concrete config (also used for the default baseline).
    pub fn run_once(&mut self, cfg: &FlagConfig) -> crate::RunMetrics {
        self.count += 1;
        let jobs = vec![
            (self.target.0, cfg.clone(), self.target.1),
            (self.other.0, self.other.1.clone(), self.other.2),
        ];
        let rs = crate::sparksim::run_parallel(
            &self.cluster,
            &jobs,
            self.seed.wrapping_add(self.count as u64),
        );
        // Tuning wall time is bounded by the slower of the two jobs.
        self.sim_time_s += rs[0].wall_clock_s.max(rs[1].wall_clock_s);
        rs.into_iter().next().unwrap()
    }
}

impl Objective for ParallelSimObjective {
    fn eval(&mut self, cfg: &FlagConfig) -> f64 {
        let m = self.run_once(cfg);
        let mut v = self.metric.of(&m);
        if m.timed_out && self.metric == Metric::HeapUsage {
            v += 50.0;
        }
        v
    }

    fn evals(&self) -> usize {
        self.count
    }

    fn sim_time_s(&self) -> f64 {
        self.sim_time_s
    }
}

/// RBO's surrogate objective: the phase-1 LR model predicts the metric
/// instead of running the benchmark ("we use a prediction model to predict
/// the metric", §III-D).
pub struct PredictorObjective {
    pub weights: Vec<f64>,
    pub xscaler: Standardizer,
    pub yscaler: TargetScaler,
    mode_encoder: crate::flags::FeatureEncoder,
    count: usize,
}

impl PredictorObjective {
    /// Fit from a phase-1 dataset through the given backend.
    pub fn fit(
        ds: &crate::datagen::Dataset,
        ridge: f64,
        backend: &std::sync::Arc<dyn crate::runtime::MlBackend>,
    ) -> anyhow::Result<Self> {
        let xscaler = Standardizer::fit(&ds.feat_rows);
        let x = xscaler.transform(&ds.feat_rows);
        let yscaler = TargetScaler::fit(&ds.y);
        let y: Vec<f64> = ds.y.iter().map(|&v| yscaler.transform(v)).collect();
        let weights = backend.lr_fit(&x, &y, ridge)?;
        Ok(PredictorObjective {
            weights,
            xscaler,
            yscaler,
            mode_encoder: crate::flags::FeatureEncoder::new(ds.mode),
            count: 0,
        })
    }

    pub fn predict(&self, cfg: &FlagConfig) -> f64 {
        let feats = self.mode_encoder.encode(cfg);
        let std = self.xscaler.transform_row(&feats);
        let z = crate::native::ops::lr_predict(&self.weights, &std);
        self.yscaler.inverse(z)
    }
}

impl Objective for PredictorObjective {
    fn eval(&mut self, cfg: &FlagConfig) -> f64 {
        self.count += 1;
        self.predict(cfg)
    }

    fn evals(&self) -> usize {
        self.count
    }

    fn sim_time_s(&self) -> f64 {
        0.0 // predictions are free — that's RBO's selling point
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flags::GcMode;
    use crate::Benchmark;

    #[test]
    fn sim_objective_accumulates_time_and_count() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let mut obj = SimObjective::new(&runner, Metric::ExecTime, 5);
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        let a = obj.eval(&cfg);
        let b = obj.eval(&cfg);
        assert!(a > 0.0 && b > 0.0);
        assert_ne!(a, b, "per-eval seeds must differ");
        assert_eq!(obj.evals(), 2);
        assert!(obj.sim_time_s() >= a + b - 1e-9);
    }

    #[test]
    fn heap_metric_objective() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let mut obj = SimObjective::new(&runner, Metric::HeapUsage, 5);
        let v = obj.eval(&FlagConfig::default_for(GcMode::G1GC));
        assert!(v > 0.0 && v < 150.0);
    }
}
