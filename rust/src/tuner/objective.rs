//! Objective functions for phase 3: the real benchmark (eq. 1's Q) and the
//! LR-predictor surrogate used by RBO.

use crate::exec::{self, ExecPool};
use crate::flags::FlagConfig;
use crate::jvmsim::FailureKind;
use crate::sparksim::{FailureHisto, SparkRunner};
use crate::util::stats::{Standardizer, TargetScaler};
use crate::Metric;

/// One objective evaluation, failure-aware: the value the tuner should
/// record (already a penalty value when the run failed), plus what
/// happened to the underlying measurement.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalOutcome {
    pub y: f64,
    /// Why the measurement failed, if it did (after any retries).
    pub failure: Option<FailureKind>,
    /// Measurement attempts consumed (1 unless a fault plan retried).
    pub attempts: u32,
}

/// Minimization objective over flag configurations.
pub trait Objective {
    /// Evaluate one configuration, reporting measurement failures.
    fn eval_outcome(&mut self, cfg: &FlagConfig) -> EvalOutcome;

    /// Evaluate one configuration (value only — failed runs still return
    /// a penalty value, so legacy callers keep working).
    fn eval(&mut self, cfg: &FlagConfig) -> f64 {
        self.eval_outcome(cfg).y
    }

    /// Evaluate a batch of configurations (q-EI's concurrent measurement
    /// round), outcomes in input order.  The contract: outcomes, eval
    /// counts, and accumulated telemetry must be **bit-identical** to
    /// calling [`Objective::eval_outcome`] on each config in order — the
    /// default does exactly that; objectives with an internal fan-out
    /// override it to run the q measurements concurrently (index-derived
    /// seeds keep the results pool-width-invariant).
    fn eval_outcomes_batch(&mut self, cfgs: &[FlagConfig]) -> Vec<EvalOutcome> {
        cfgs.iter().map(|c| self.eval_outcome(c)).collect()
    }

    /// Benchmark executions consumed so far.
    fn evals(&self) -> usize;

    /// Simulated benchmark wall time consumed so far (seconds).
    fn sim_time_s(&self) -> f64;

    /// Per-kind failure counts accumulated over this objective's life.
    /// Surrogate objectives that cannot fail report an empty histogram.
    fn failures(&self) -> FailureHisto {
        FailureHisto::default()
    }
}

/// The real objective: run the benchmark on the simulated cluster.
pub struct SimObjective<'a> {
    pub runner: &'a SparkRunner,
    pub metric: Metric,
    seed: u64,
    count: usize,
    sim_time_s: f64,
    failures: FailureHisto,
    /// Pool for the per-executor fan-out inside each run.  The global pool
    /// when this objective is the only thing running (a lone tuning job);
    /// serial when the caller already fans several tuners out in parallel
    /// (`run_pipeline`'s algorithm sweep) — results are identical either
    /// way, only thread scheduling differs.
    pool: ExecPool,
}

impl<'a> SimObjective<'a> {
    pub fn new(runner: &'a SparkRunner, metric: Metric, seed: u64) -> Self {
        Self::new_on(runner, metric, seed, *exec::global())
    }

    /// `new` with an explicit per-run executor fan-out pool.
    pub fn new_on(runner: &'a SparkRunner, metric: Metric, seed: u64, pool: ExecPool) -> Self {
        SimObjective {
            runner,
            metric,
            seed,
            count: 0,
            sim_time_s: 0.0,
            failures: FailureHisto::default(),
            pool,
        }
    }
}

impl Objective for SimObjective<'_> {
    fn eval_outcome(&mut self, cfg: &FlagConfig) -> EvalOutcome {
        self.count += 1;
        let out =
            self.runner.run_outcome_on(&self.pool, cfg, self.seed.wrapping_add(self.count as u64));
        let m = out.metrics();
        self.sim_time_s += m.wall_clock_s;
        let mut v = self.metric.of(m);
        if let Some(kind) = out.failure() {
            self.failures.record(kind);
            if self.metric == Metric::HeapUsage {
                v += 50.0; // a crashing config must not win the memory race
            }
        }
        EvalOutcome { y: v, failure: out.failure(), attempts: out.attempts() }
    }

    /// Concurrent batch: fan the q runs out on this objective's pool
    /// (each run's *inner* per-executor fan-out goes serial — run results
    /// are pool-width-invariant, so moving the parallelism one level up
    /// changes nothing), with the exact per-run seeds the sequential
    /// path would have drawn (`seed + count + i + 1`).  Telemetry is
    /// folded in input order afterwards, so counts, histograms, and
    /// accumulated sim time are bit-identical to q sequential
    /// `eval_outcome` calls at any pool width.
    fn eval_outcomes_batch(&mut self, cfgs: &[FlagConfig]) -> Vec<EvalOutcome> {
        let (runner, seed, base) = (self.runner, self.seed, self.count);
        let inner = ExecPool::serial();
        let outs = self.pool.par_map(cfgs, |i, cfg| {
            runner.run_outcome_on(&inner, cfg, seed.wrapping_add((base + i + 1) as u64))
        });
        let mut res = Vec::with_capacity(outs.len());
        for out in outs {
            self.count += 1;
            let m = out.metrics();
            self.sim_time_s += m.wall_clock_s;
            let mut v = self.metric.of(m);
            if let Some(kind) = out.failure() {
                self.failures.record(kind);
                if self.metric == Metric::HeapUsage {
                    v += 50.0;
                }
            }
            res.push(EvalOutcome { y: v, failure: out.failure(), attempts: out.attempts() });
        }
        res
    }

    fn evals(&self) -> usize {
        self.count
    }

    fn sim_time_s(&self) -> f64 {
        self.sim_time_s
    }

    fn failures(&self) -> FailureHisto {
        self.failures
    }
}

/// Objective for the parallel-run scenario (paper §V-E / Fig 6): the tuned
/// benchmark runs concurrently with a second job (at its default flags) on
/// the shared cluster, and the tuned job's metric is returned.
pub struct ParallelSimObjective {
    pub cluster: crate::sparksim::ClusterSpec,
    pub target: (crate::Benchmark, crate::sparksim::ExecutorSpec),
    pub other: (crate::Benchmark, FlagConfig, crate::sparksim::ExecutorSpec),
    pub metric: Metric,
    seed: u64,
    count: usize,
    sim_time_s: f64,
    failures: FailureHisto,
}

impl ParallelSimObjective {
    pub fn new(
        cluster: crate::sparksim::ClusterSpec,
        target: (crate::Benchmark, crate::sparksim::ExecutorSpec),
        other: (crate::Benchmark, FlagConfig, crate::sparksim::ExecutorSpec),
        metric: Metric,
        seed: u64,
    ) -> Self {
        ParallelSimObjective {
            cluster,
            target,
            other,
            metric,
            seed,
            count: 0,
            sim_time_s: 0.0,
            failures: FailureHisto::default(),
        }
    }

    /// Evaluate a concrete config (also used for the default baseline).
    pub fn run_once(&mut self, cfg: &FlagConfig) -> crate::RunMetrics {
        self.count += 1;
        let jobs = vec![
            (self.target.0, cfg.clone(), self.target.1),
            (self.other.0, self.other.1.clone(), self.other.2),
        ];
        let rs = crate::sparksim::run_parallel(
            &self.cluster,
            &jobs,
            self.seed.wrapping_add(self.count as u64),
        );
        // Tuning wall time is bounded by the slower of the two jobs.
        self.sim_time_s += rs[0].wall_clock_s.max(rs[1].wall_clock_s);
        rs.into_iter().next().unwrap()
    }
}

impl Objective for ParallelSimObjective {
    fn eval_outcome(&mut self, cfg: &FlagConfig) -> EvalOutcome {
        let m = self.run_once(cfg);
        let mut v = self.metric.of(&m);
        if let Some(kind) = m.failure {
            self.failures.record(kind);
            if self.metric == Metric::HeapUsage {
                v += 50.0;
            }
        }
        EvalOutcome { y: v, failure: m.failure, attempts: 1 }
    }

    fn evals(&self) -> usize {
        self.count
    }

    fn sim_time_s(&self) -> f64 {
        self.sim_time_s
    }

    fn failures(&self) -> FailureHisto {
        self.failures
    }
}

/// RBO's surrogate objective: the phase-1 LR model predicts the metric
/// instead of running the benchmark ("we use a prediction model to predict
/// the metric", §III-D).
pub struct PredictorObjective {
    pub weights: Vec<f64>,
    pub xscaler: Standardizer,
    pub yscaler: TargetScaler,
    mode_encoder: crate::flags::FeatureEncoder,
    count: usize,
}

impl PredictorObjective {
    /// Fit from a phase-1 dataset through the given backend.
    pub fn fit(
        ds: &crate::datagen::Dataset,
        ridge: f64,
        backend: &std::sync::Arc<dyn crate::runtime::MlBackend>,
    ) -> anyhow::Result<Self> {
        let xscaler = Standardizer::fit(&ds.feat_rows);
        let x = xscaler.transform(&ds.feat_rows);
        let yscaler = TargetScaler::fit(&ds.y);
        let y: Vec<f64> = ds.y.iter().map(|&v| yscaler.transform(v)).collect();
        let weights = backend.lr_fit(&x, &y, ridge)?;
        Ok(PredictorObjective {
            weights,
            xscaler,
            yscaler,
            mode_encoder: crate::flags::FeatureEncoder::new(ds.mode),
            count: 0,
        })
    }

    pub fn predict(&self, cfg: &FlagConfig) -> f64 {
        let feats = self.mode_encoder.encode(cfg);
        let std = self.xscaler.transform_row(&feats);
        let z = crate::native::ops::lr_predict(&self.weights, &std);
        self.yscaler.inverse(z)
    }
}

impl Objective for PredictorObjective {
    fn eval_outcome(&mut self, cfg: &FlagConfig) -> EvalOutcome {
        self.count += 1;
        EvalOutcome { y: self.predict(cfg), failure: None, attempts: 1 }
    }

    fn evals(&self) -> usize {
        self.count
    }

    fn sim_time_s(&self) -> f64 {
        0.0 // predictions are free — that's RBO's selling point
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flags::GcMode;
    use crate::sparksim::FaultPlan;
    use crate::Benchmark;

    #[test]
    fn sim_objective_accumulates_time_and_count() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let mut obj = SimObjective::new(&runner, Metric::ExecTime, 5);
        let cfg = FlagConfig::default_for(GcMode::G1GC);
        let a = obj.eval(&cfg);
        let b = obj.eval(&cfg);
        assert!(a > 0.0 && b > 0.0);
        assert_ne!(a, b, "per-eval seeds must differ");
        assert_eq!(obj.evals(), 2);
        assert!(obj.sim_time_s() >= a + b - 1e-9);
        assert!(obj.failures().is_empty());
    }

    #[test]
    fn heap_metric_objective() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let mut obj = SimObjective::new(&runner, Metric::HeapUsage, 5);
        let v = obj.eval(&FlagConfig::default_for(GcMode::G1GC));
        assert!(v > 0.0 && v < 150.0);
    }

    #[test]
    fn sim_objective_records_failures() {
        // A too-small heap OOMs deterministically: the histogram sees it
        // and the reported value is the exec-time penalty.
        let runner = SparkRunner::paper_default(Benchmark::DenseKMeans);
        let mut obj = SimObjective::new(&runner, Metric::ExecTime, 5);
        let mut cfg = FlagConfig::default_for(GcMode::ParallelGC);
        cfg.set("MaxHeapSize", 2048.0);
        let out = obj.eval_outcome(&cfg);
        assert_eq!(out.failure, Some(FailureKind::Oom));
        assert_eq!(out.attempts, 1);
        assert_eq!(obj.failures().oom, 1);
        assert!(out.y > 1000.0, "failed run must report the penalty, got {}", out.y);
    }

    /// The batch path must be bit-identical to q sequential evals — same
    /// outcomes, same seed stream, same telemetry — at any pool width,
    /// including under injected faults, and a single eval *after* a batch
    /// must continue the same per-run seed stream.
    #[test]
    fn batch_eval_matches_sequential_bitwise_at_any_width() {
        use crate::util::rng::Pcg;
        let plan = FaultPlan { seed: 9, crash_p: 0.3, max_retries: 2, ..Default::default() };
        let runner = SparkRunner::paper_default(Benchmark::Lda).with_faults(plan);
        let mut rng = Pcg::new(41);
        let cfgs: Vec<FlagConfig> =
            (0..5).map(|_| FlagConfig::random(GcMode::G1GC, &mut rng)).collect();
        let tail = FlagConfig::default_for(GcMode::G1GC);

        let mut seq = SimObjective::new_on(&runner, Metric::ExecTime, 7, ExecPool::serial());
        let expect: Vec<EvalOutcome> = cfgs.iter().map(|c| seq.eval_outcome(c)).collect();
        let expect_tail = seq.eval_outcome(&tail);

        for width in [1usize, 2, 8] {
            let mut obj =
                SimObjective::new_on(&runner, Metric::ExecTime, 7, ExecPool::new(width));
            let got = obj.eval_outcomes_batch(&cfgs);
            assert_eq!(got, expect, "batch outcomes diverged at width {width}");
            assert_eq!(obj.evals(), cfgs.len());
            let got_tail = obj.eval_outcome(&tail);
            assert_eq!(got_tail, expect_tail, "post-batch seed stream broke at width {width}");
            assert_eq!(obj.evals(), seq.evals());
            assert_eq!(
                obj.sim_time_s().to_bits(),
                seq.sim_time_s().to_bits(),
                "sim-time fold diverged at width {width}"
            );
            assert_eq!(obj.failures(), seq.failures(), "histograms diverged at width {width}");
        }
    }

    #[test]
    fn sim_objective_counts_injected_faults() {
        let plan = FaultPlan { seed: 4, crash_p: 1.0, max_retries: 1, ..Default::default() };
        let runner = SparkRunner::paper_default(Benchmark::Lda).with_faults(plan);
        let mut obj = SimObjective::new(&runner, Metric::ExecTime, 5);
        let out = obj.eval_outcome(&FlagConfig::default_for(GcMode::G1GC));
        assert_eq!(out.failure, Some(FailureKind::Crash));
        assert_eq!(out.attempts, 2, "one retry before giving up");
        assert_eq!(obj.failures().crash, 1);
    }
}
