//! Phase 3 — recommending the best flag configuration (paper §III-D).
//!
//! Four optimizers over the lasso-selected flag subspace:
//! * `BoTuner` — Bayesian Optimization: SOBOL init, stateful GP surrogate
//!   session (incremental cached Cholesky on the native backend, the
//!   `gp_ei` HLO artifact on XLA) + pool-sharded EI acquisition
//!   (Algorithm 2);
//! * `BoTuner::warm_start` — GP seeded with the phase-1 AL data instead of
//!   SOBOL points;
//! * `RboTuner` — Regression-guided BO: the phase-1 LR model replaces the
//!   benchmark as the objective (≈6x cheaper per the paper);
//! * `SaTuner` — the Simulated Annealing + Latin-Hypercube baseline
//!   (§IV-E).

pub mod bo;
pub mod objective;
pub mod rbo;
pub mod sa;
pub mod space;

pub use bo::BoTuner;
pub use objective::{EvalOutcome, Objective, ParallelSimObjective, SimObjective};
pub use rbo::RboTuner;
pub use sa::SaTuner;
pub use space::TuneSpace;

use anyhow::Result;

use crate::exec::JobControl;
use crate::flags::FlagConfig;

/// Result of one tuning run.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub algo: String,
    pub best_config: FlagConfig,
    pub best_y: f64,
    /// Objective value observed at each iteration (evaluation order).
    pub history: Vec<f64>,
    /// Running best after each iteration.
    pub best_history: Vec<f64>,
    /// Number of real benchmark evaluations consumed.
    pub evals: usize,
    /// Simulated benchmark wall time consumed by those evaluations (s) —
    /// the dominant term of the paper's §V-C tuning-time comparison.
    pub sim_time_s: f64,
    /// Optimizer-side wall time actually measured (ms).
    pub algo_wall_ms: f64,
    /// Final GP surrogate hyper-parameters (per-dimension length-scales
    /// in tuning-space dimension order, noise variance) — the warm-start
    /// payload for a follow-up job (`tune --gp-init-hypers`, REST
    /// `gp_init_hypers`).  `None` for tuners without a GP surrogate (SA).
    pub gp_hypers: Option<(Vec<f64>, f64)>,
    /// Normalized ARD relevance (1/ℓⱼ², scaled to sum to 1) over the
    /// tuned dimensions — present only when the surrogate adapted with
    /// ARD, so the pipeline can cross-check it against the lasso
    /// `featsel::Selection` (the paper's feature-selection stage).
    pub ard_relevance: Option<Vec<f64>>,
    /// Per-kind measurement-failure histogram accumulated by the
    /// objective over this run (all zeros on a fault-free run).
    pub failures: crate::sparksim::FailureHisto,
}

/// Common interface for all phase-3 optimizers.
pub trait Tuner {
    fn name(&self) -> String;

    /// Run `iters` tuning iterations against `objective` over `space`.
    fn tune(
        &mut self,
        space: &TuneSpace,
        objective: &mut dyn Objective,
        iters: usize,
    ) -> Result<TuneResult> {
        self.tune_ctl(space, objective, iters, &JobControl::default())
    }

    /// [`Tuner::tune`] under a [`JobControl`]: the loop publishes progress
    /// (`iteration`, `best_y`) and polls for cooperative cancellation at
    /// every iteration boundary.  A cancelled run is not an error — it
    /// returns the best-so-far partial [`TuneResult`].
    fn tune_ctl(
        &mut self,
        space: &TuneSpace,
        objective: &mut dyn Objective,
        iters: usize,
        ctl: &JobControl,
    ) -> Result<TuneResult>;
}
