//! The tuning subspace: the lasso-selected flags vary, everything else
//! stays at its JVM default (how the paper shrinks the search space).

use crate::featsel::Selection;
use crate::flags::{FeatureEncoder, FlagConfig, GcMode};
use crate::util::rng::Pcg;

#[derive(Clone, Debug)]
pub struct TuneSpace {
    pub mode: GcMode,
    /// Flag positions (within the GC group) being tuned.
    pub selected: Vec<usize>,
    base: FlagConfig,
}

impl TuneSpace {
    /// Tune every flag in the group (feature selection skipped).
    pub fn full(mode: GcMode) -> TuneSpace {
        let enc = FeatureEncoder::new(mode);
        TuneSpace {
            mode,
            selected: (0..enc.n_flags()).collect(),
            base: FlagConfig::default_for(mode),
        }
    }

    /// Tune only the lasso-selected flags.
    pub fn from_selection(mode: GcMode, sel: &Selection) -> TuneSpace {
        assert!(!sel.selected.is_empty(), "empty selection");
        TuneSpace {
            mode,
            selected: sel.selected.clone(),
            base: FlagConfig::default_for(mode),
        }
    }

    /// Dimensionality of the search cube.
    pub fn dim(&self) -> usize {
        self.selected.len()
    }

    /// Materialize a point u in [0,1]^dim as a full flag configuration
    /// (unselected flags keep their defaults).
    pub fn to_config(&self, u: &[f64]) -> FlagConfig {
        assert_eq!(u.len(), self.dim());
        let mut unit = self.base.to_unit();
        for (&pos, &v) in self.selected.iter().zip(u) {
            unit[pos] = v.clamp(0.0, 1.0);
        }
        FlagConfig::from_unit(self.mode, &unit)
    }

    /// Project a full config onto the tuned dimensions.
    pub fn project(&self, cfg: &FlagConfig) -> Vec<f64> {
        assert_eq!(cfg.mode, self.mode);
        let unit = cfg.to_unit();
        self.selected.iter().map(|&p| unit[p]).collect()
    }

    /// Project a full-group unit row (e.g. a phase-1 dataset row).
    pub fn project_unit(&self, unit: &[f64]) -> Vec<f64> {
        self.selected.iter().map(|&p| unit[p]).collect()
    }

    /// The default configuration's position in the cube.
    pub fn default_point(&self) -> Vec<f64> {
        self.project(&self.base)
    }

    /// Uniform random point.
    pub fn random_point(&self, rng: &mut Pcg) -> Vec<f64> {
        (0..self.dim()).map(|_| rng.f64()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space3() -> TuneSpace {
        TuneSpace {
            mode: GcMode::G1GC,
            selected: vec![0, 5, 17],
            base: FlagConfig::default_for(GcMode::G1GC),
        }
    }

    #[test]
    fn full_space_covers_group() {
        assert_eq!(TuneSpace::full(GcMode::ParallelGC).dim(), 126);
        assert_eq!(TuneSpace::full(GcMode::G1GC).dim(), 141);
    }

    #[test]
    fn to_config_touches_only_selected() {
        let sp = space3();
        let cfg = sp.to_config(&[0.0, 1.0, 0.5]);
        let default = FlagConfig::default_for(GcMode::G1GC);
        let mut diffs = 0;
        for (i, (a, b)) in cfg.values.iter().zip(&default.values).enumerate() {
            if (a - b).abs() > 1e-9 {
                assert!(sp.selected.contains(&i), "flag {i} changed unexpectedly");
                diffs += 1;
            }
        }
        assert!(diffs >= 2); // 0.5 may round to the default for some flags
    }

    #[test]
    fn project_roundtrip() {
        let sp = space3();
        let u = [0.25, 0.75, 0.5];
        let cfg = sp.to_config(&u);
        let back = sp.project(&cfg);
        for (a, b) in u.iter().zip(&back) {
            // quantization by integer flags allowed
            assert!((a - b).abs() < 0.1, "{a} vs {b}");
        }
    }

    #[test]
    fn default_point_maps_to_default_config() {
        let sp = space3();
        let cfg = sp.to_config(&sp.default_point());
        let default = FlagConfig::default_for(GcMode::G1GC);
        for (f, (a, b)) in cfg.defs().iter().zip(cfg.values.iter().zip(&default.values)) {
            let tol = match f.kind {
                crate::flags::Kind::Bool { .. } => 0.0,
                crate::flags::Kind::Int { min, max, log, .. } => {
                    if log { (b * 0.02).max(1.0) } else { ((max - min) * 2e-3).max(1.0) }
                }
            };
            assert!((a - b).abs() <= tol, "{}: {a} vs {b}", f.name);
        }
    }

    #[test]
    fn random_points_in_cube() {
        let sp = space3();
        let mut rng = Pcg::new(3);
        for _ in 0..50 {
            let u = sp.random_point(&mut rng);
            assert_eq!(u.len(), 3);
            assert!(u.iter().all(|&x| (0.0..1.0).contains(&x)));
        }
    }
}
