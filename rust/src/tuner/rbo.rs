//! Regression-guided Bayesian Optimization (paper §III-D): identical BO
//! loop, but the objective is the phase-1 LR predictor instead of a real
//! benchmark run — "instead of running the application to evaluate the
//! chosen flag configurations, we use a prediction model to predict the
//! metric".  The recommended configuration is validated with one real run
//! at the end.  The inner loop inherits `BoConfig`'s surrogate session and
//! exec pool, so RBO's many cheap predictor iterations ride the same
//! incremental cached-Cholesky surrogate as plain BO — including
//! `GpHypers::mode`: with `HyperMode::Adapt` the inner surrogate adapts
//! its length-scale/noise to the predictor's response surface and evicts
//! via the O(n²) downdate, which matters here because RBO typically runs
//! many more (cheap) iterations than plain BO and crosses the N_TRAIN
//! eviction threshold sooner.  `BoConfig::batch_q` inherits the same way:
//! an RBO with q > 1 proposes q predictor evaluations per inner
//! iteration via the constant-liar fantasy scope (cheap either way — the
//! predictor objective has no fan-out, so its batch round is the
//! sequential default).

use std::time::Instant;

use anyhow::Result;

use super::bo::{BoConfig, BoTuner};
use super::objective::{Objective, PredictorObjective};
use super::space::TuneSpace;
use super::{TuneResult, Tuner};
use crate::datagen::Dataset;
use crate::exec::JobControl;
use crate::runtime::MlBackend;

pub struct RboTuner {
    backend: std::sync::Arc<dyn MlBackend>,
    pub cfg: BoConfig,
    dataset: Dataset,
    pub ridge: f64,
}

impl RboTuner {
    pub fn new(
        backend: std::sync::Arc<dyn MlBackend>,
        cfg: BoConfig,
        dataset: Dataset,
    ) -> Self {
        RboTuner { backend, cfg, dataset, ridge: 1e-3 }
    }
}

impl Tuner for RboTuner {
    fn name(&self) -> String {
        "rbo".into()
    }

    /// `objective` here is the *real* objective; it is consulted only once,
    /// to validate the predictor-chosen configuration.  The inner
    /// surrogate loop inherits `ctl`, so cancellation lands between its
    /// (cheap) predictor iterations; the final validation runs still
    /// execute so a cancelled RBO reports a *measured* best-so-far.
    fn tune_ctl(
        &mut self,
        space: &TuneSpace,
        objective: &mut dyn Objective,
        iters: usize,
        ctl: &JobControl,
    ) -> Result<TuneResult> {
        let t0 = Instant::now(); // detlint: allow(wall-clock) -- tuning_time_s telemetry; result values are seed-derived
        let mut predictor = PredictorObjective::fit(&self.dataset, self.ridge, &self.backend)?;

        // Trust region: the LR predictor is only valid near its training
        // data, so anchor the surrogate's candidate sampling there.
        let mut cfg = self.cfg.clone();
        cfg.anchors = Some(
            self.dataset
                .unit_rows
                .iter()
                .map(|u| space.project_unit(u))
                .collect(),
        );
        let mut inner = BoTuner::new(self.backend.clone(), cfg);
        let surrogate_result = inner.tune_ctl(space, &mut predictor, iters, ctl)?;

        // Guard against predictor over-optimism (a linear model happily
        // extrapolates into OOM territory): validate the surrogate's pick
        // with one real run and compare against the best configuration
        // phase 1 already *measured*.  RBO thus costs at most two real
        // runs — still ~10x cheaper than the 20-iteration BO loop.
        let ds_best_i = crate::util::stats::argmin(&self.dataset.y);
        let ds_best_cfg = crate::flags::FlagConfig::from_unit(
            self.dataset.mode,
            &self.dataset.unit_rows[ds_best_i],
        );
        let surrogate_y = objective.eval(&surrogate_result.best_config);
        let ds_best_y = objective.eval(&ds_best_cfg);
        let (best_config, real_y) = if surrogate_y <= ds_best_y {
            (surrogate_result.best_config, surrogate_y)
        } else {
            (ds_best_cfg, ds_best_y)
        };

        Ok(TuneResult {
            algo: self.name(),
            best_config,
            best_y: real_y,
            history: surrogate_result.history,
            best_history: surrogate_result.best_history,
            evals: objective.evals(),
            sim_time_s: objective.sim_time_s(),
            algo_wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            // The inner surrogate's adapted hypers/ARD relevance describe
            // the same tuning subspace, so they carry over verbatim.
            gp_hypers: surrogate_result.gp_hypers,
            ard_relevance: surrogate_result.ard_relevance,
            // Only the *real* validation runs can fail; the predictor
            // objective driving the inner loop cannot.
            failures: objective.failures(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{characterize, DataGenConfig, Strategy};
    use crate::flags::GcMode;
    use crate::runtime::NativeBackend;
    use crate::sparksim::SparkRunner;
    use crate::tuner::objective::SimObjective;
    use crate::{Benchmark, Metric};
    use std::sync::Arc;

    #[test]
    fn rbo_consumes_one_real_run() {
        let runner = SparkRunner::paper_default(Benchmark::Lda);
        let backend: Arc<dyn crate::runtime::MlBackend> = Arc::new(NativeBackend);
        let dg = DataGenConfig {
            pool_size: 150,
            seed_runs: 20,
            test_runs: 8,
            batch_k: 15,
            max_rounds: 3,
            rmse_rel_tol: 0.0,
            ridge: 1e-3,
            seed: 3,
        };
        let ch = characterize(
            &runner,
            GcMode::G1GC,
            Metric::ExecTime,
            Strategy::Bemcm,
            &dg,
            &backend,
        )
        .unwrap();
        let sel = crate::featsel::select_flags(&ch.dataset, 0.01, &backend).unwrap();
        let space = TuneSpace::from_selection(GcMode::G1GC, &sel);
        let mut obj = SimObjective::new(&runner, Metric::ExecTime, 9);
        let mut rbo = RboTuner::new(
            backend.clone(),
            BoConfig { n_init: 6, n_candidates: 128, ..Default::default() },
            ch.dataset.clone(),
        );
        let r = rbo.tune(&space, &mut obj, 8).unwrap();
        assert_eq!(r.evals, 2, "RBO runs the benchmark at most twice");
        assert!(r.best_y > 0.0);
        // Its sim time is a tiny fraction of what BO would burn (8+ runs).
        assert!(r.sim_time_s < 400.0);
    }
}
