//! Bayesian Optimization (paper Algorithm 2) and its warm-start variant.
//!
//! SOBOL-initialized GP with Expected Improvement; each acquisition sweep
//! evaluates EI over a candidate pool (quasi-random global points + local
//! perturbations of the incumbent) through the backend's GP surrogate
//! *session* ([`MlBackend::gp_open`]): observations accumulate across
//! iterations (native backend: incremental cached Cholesky, candidates
//! sharded on the exec pool), instead of refitting the kernel from scratch
//! every sweep.  [`SurrogateMode::OneShot`] keeps the old refit-per-sweep
//! `gp_ei` path as the bit-identical cross-check reference.
//! [`GpHypers::mode`] selects the session's hyper-parameter policy:
//! `HyperMode::Fixed` (default) preserves that bitwise contract;
//! `HyperMode::Adapt` turns on marginal-likelihood adaptation and O(n²)
//! downdate evictions in the native session.  [`GpHypers::ard`] frees the
//! per-dimension length-scales during adaptation (Automatic Relevance
//! Determination) and makes the result carry a normalized relevance
//! vector over the tuned flags; [`GpHypers::init`] warm-starts the
//! session at a previous job's adapted hypers.
//!
//! **Batched proposal (q-EI, constant-liar):** [`BoConfig::batch_q`] > 1
//! proposes q points per iteration by maximizing EI sequentially against
//! a session temporarily extended with *fantasy* observations at the
//! constant liar — the worst target observed so far, so the fantasized
//! model only flattens EI around already-claimed picks, never invents
//! optimism.  Fantasies ride the session's O(n²)
//! [`GpSession::fantasize`]/[`GpSession::pop_fantasy`] scope and are all
//! retracted before the q real measurements run concurrently through
//! [`Objective::eval_outcomes_batch`]; every outcome is then observed in
//! pick order (failures individually quarantined and penalized) before
//! the next acquisition round.  `batch_q = 1` (the default) takes the
//! exact legacy single-point code path, bitwise identical at every pool
//! width (`tests/gp_incremental.rs`).
//!
//! **Init-design failure semantics:** a failed measurement in the
//! initial design gets the same worst-observed penalty the iteration
//! loop applies — computed once after the whole init sweep, in
//! deterministic order — and the incumbent (`best_y`/`best_x`) is
//! selected over *successful* runs only.  A crash's garbage reading can
//! therefore neither poison the surrogate nor seed the incumbent (it
//! used to do both; the regression tests below and
//! `tests/exec_parallel.rs` pin the fix).

use std::collections::HashSet;
use std::time::Instant;

use anyhow::Result;

use super::objective::Objective;
use super::space::TuneSpace;
use super::{TuneResult, Tuner};
use crate::exec::{self, ExecPool, JobControl};
use crate::runtime::{GpConfig, GpSession, HyperMode, KernelPolicy, MlBackend, N_TRAIN};
use crate::util::rng::Pcg;
use crate::util::sobol::Sobol;
use crate::util::stats::argmax;

/// GP hyper-parameters (y is standardized before fitting, so the signal
/// variance is ~1; the lengthscale scales with sqrt(dim) because distances
/// in the unit cube grow with dimension).
#[derive(Clone, Debug)]
pub struct GpHypers {
    pub lengthscale_per_sqrt_dim: f64,
    pub sigma_f2: f64,
    pub sigma_n2: f64,
    /// Hyper-parameter policy for the surrogate session.  `Fixed` (the
    /// default) keeps the bitwise session-vs-one-shot contract; `Adapt`
    /// lets the native session run marginal-likelihood ascent over the
    /// length-scales and noise as observations stream in, and evict via
    /// the O(n²) Cholesky downdate.  One-shot surrogates (and the XLA
    /// engine's sessions) ignore `Adapt` and stay fixed.
    pub mode: HyperMode,
    /// Automatic Relevance Determination: under `Adapt`, every tuned
    /// dimension's length-scale moves independently (d+1 free
    /// parameters) instead of as one tied scalar, and the result carries
    /// a normalized per-dimension relevance vector next to the lasso
    /// selection.  Isotropic (off) stays the default.
    pub ard: bool,
    /// Linear-algebra tier for the native surrogate's hot loops:
    /// `Scalar` (the default) is bitwise-pinned to the one-shot
    /// reference; `Blocked` runs the panel/lane kernels — 1e-8 from
    /// Scalar, bitwise self-reproducible at any pool width.  One-shot
    /// surrogates and the XLA engine ignore it.
    pub kernels: KernelPolicy,
    /// Warm-start initial hypers from a previous job's `TuneResult`:
    /// per-dimension length-scales (must match the tuning dimension —
    /// `tune_ctl` errors otherwise) plus noise variance.  Overrides
    /// `lengthscale_per_sqrt_dim`/`sigma_n2` when present.
    pub init: Option<(Vec<f64>, f64)>,
}

impl Default for GpHypers {
    fn default() -> Self {
        GpHypers {
            lengthscale_per_sqrt_dim: 0.30,
            sigma_f2: 1.0,
            sigma_n2: 0.01,
            mode: HyperMode::Fixed,
            ard: false,
            kernels: KernelPolicy::Scalar,
            init: None,
        }
    }
}

/// Which surrogate implementation the BO loop drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SurrogateMode {
    /// The backend's stateful session (native: incremental cached
    /// Cholesky + pool-sharded acquisition).
    Session,
    /// Re-fit from scratch through one-shot `gp_ei` every iteration —
    /// the cross-check reference (`tests/gp_incremental.rs` proves both
    /// modes bit-identical).
    OneShot,
}

#[derive(Clone, Debug)]
pub struct BoConfig {
    /// SOBOL initialization points (ignored with a warm-start dataset).
    pub n_init: usize,
    /// Candidate pool per acquisition sweep.
    pub n_candidates: usize,
    /// Fraction of candidates sampled as local perturbations of the best.
    pub local_frac: f64,
    pub local_sigma: f64,
    pub hypers: GpHypers,
    pub seed: u64,
    /// Optional trust region: when set, "global" candidates are sampled as
    /// perturbations of these anchor points instead of uniformly — used by
    /// RBO to keep the surrogate inside the region its LR predictor was
    /// trained on (a linear model extrapolates to cube corners otherwise).
    pub anchors: Option<Vec<Vec<f64>>>,
    pub anchor_sigma: f64,
    /// Seed the initial design with the JVM default configuration (real
    /// tuning always knows where it starts from).
    pub include_default: bool,
    /// Surrogate implementation (session vs one-shot cross-check).
    pub surrogate: SurrogateMode,
    /// Pool the acquisition scoring shards on; width never changes
    /// results (index-ordered fixed-size blocks).
    pub epool: ExecPool,
    /// Safe-baseline bound for failure-aware acquisition: when set,
    /// candidates whose GP posterior mean predicts a value *worse* than
    /// this baseline are rejected (the online-safe-tuning guard), falling
    /// back to plain argmax-EI when no candidate qualifies.  `None` (the
    /// default) keeps the acquisition pick bitwise identical to the
    /// legacy path.
    pub safe_baseline: Option<f64>,
    /// Points proposed per BO iteration (q-EI).  q > 1 selects q
    /// candidates sequentially against constant-liar fantasized models
    /// and measures them concurrently via
    /// [`Objective::eval_outcomes_batch`]; each pick stays quarantine-
    /// and safe-baseline-aware.  1 (the default) is the legacy
    /// single-point path, bitwise unchanged.  Must be >= 1 and <=
    /// `n_candidates` (`tune_ctl` validates before any evaluation runs).
    pub batch_q: usize,
}

impl Default for BoConfig {
    fn default() -> Self {
        BoConfig {
            n_init: 8,
            n_candidates: 1024,
            local_frac: 0.6,
            local_sigma: 0.08,
            hypers: GpHypers::default(),
            seed: 0xb0,
            anchors: None,
            anchor_sigma: 0.06,
            include_default: true,
            surrogate: SurrogateMode::Session,
            epool: *exec::global(),
            safe_baseline: None,
            batch_q: 1,
        }
    }
}

/// Salt decorrelating the Sobol-padding streams (dimensions past the
/// generator's `MAX_DIM`) from every other consumer of the tuner seed.
const SOBOL_PAD_SALT: u64 = 0x50B0_1FAD;

/// Fill the dimensions past the Sobol generator's `MAX_DIM` with a
/// seeded per-point stream.  Padding them all with a frozen 0.5 (the old
/// behaviour) made every init point identical in those dimensions —
/// duplicated kernel columns and zero exploration there.  Each point
/// gets its own `index_seed`-derived stream, so padded coordinates are
/// distinct across points yet bitwise reproducible; spaces at or under
/// `MAX_DIM` never reach this (strict no-op, no RNG constructed).
fn pad_init_point(u: &mut Vec<f64>, dim: usize, seed: u64, point_index: u64) {
    if u.len() >= dim {
        return;
    }
    let mut pad = Pcg::new(exec::index_seed(seed ^ SOBOL_PAD_SALT, point_index));
    while u.len() < dim {
        u.push(pad.f64());
    }
}

/// Bit-pattern key for a unit-cube point (quarantine-set membership is
/// exact — the same proposed point hashes identically).
fn unit_key(u: &[f64]) -> Vec<u64> {
    u.iter().map(|v| v.to_bits()).collect()
}

/// Failure-aware candidate choice.  With no quarantined configs and no
/// baseline this *is* `argmax(ei)` — same index, same tie-breaking — so
/// the happy path stays bitwise unchanged.  Otherwise: argmax EI over
/// non-quarantined candidates predicted no worse than the baseline,
/// falling back to non-quarantined argmax EI, then to the plain pick.
fn pick_candidate(
    cands: &[Vec<f64>],
    ei: &[f64],
    mu: &[f64],
    baseline: Option<f64>,
    quarantine: &HashSet<Vec<u64>>,
) -> usize {
    if quarantine.is_empty() && baseline.is_none() {
        return argmax(ei);
    }
    let allowed =
        |i: usize| quarantine.is_empty() || !quarantine.contains(&unit_key(&cands[i]));
    let mut best: Option<usize> = None;
    if let Some(b) = baseline {
        for i in 0..cands.len() {
            if ei[i].is_nan() {
                continue;
            }
            let better = match best {
                None => true,
                Some(j) => ei[i] > ei[j],
            };
            if allowed(i) && mu[i] <= b && better {
                best = Some(i);
            }
        }
    }
    if best.is_none() {
        for i in 0..cands.len() {
            if ei[i].is_nan() {
                continue;
            }
            let better = match best {
                None => true,
                Some(j) => ei[i] > ei[j],
            };
            if allowed(i) && better {
                best = Some(i);
            }
        }
    }
    best.unwrap_or_else(|| argmax(ei))
}

pub struct BoTuner {
    pub cfg: BoConfig,
    backend: std::sync::Arc<dyn MlBackend>,
    /// Warm-start data: (projected point, objective value) pairs from the
    /// phase-1 AL dataset ("BO with warm start", §III-D).
    warm: Option<Vec<(Vec<f64>, f64)>>,
}

impl BoTuner {
    pub fn new(backend: std::sync::Arc<dyn MlBackend>, cfg: BoConfig) -> Self {
        BoTuner { cfg, backend, warm: None }
    }

    /// Warm-start variant: seed the GP with the AL characterization data
    /// projected onto the tuning subspace (no SOBOL burn-in runs).
    pub fn warm_start(
        backend: std::sync::Arc<dyn MlBackend>,
        cfg: BoConfig,
        space: &TuneSpace,
        ds: &crate::datagen::Dataset,
    ) -> Self {
        let mut warm: Vec<(Vec<f64>, f64)> = ds
            .unit_rows
            .iter()
            .zip(&ds.y)
            .map(|(u, &y)| (space.project_unit(u), y))
            .collect();
        // Keep the most recent rows if the dataset exceeds the GP budget.
        let cap = N_TRAIN.saturating_sub(64); // leave room for BO iterations
        if warm.len() > cap {
            warm.drain(..warm.len() - cap);
        }
        BoTuner { cfg, backend, warm: Some(warm) }
    }

    fn candidates(&self, space: &TuneSpace, best: &[f64], rng: &mut Pcg) -> Vec<Vec<f64>> {
        let n_local = (self.cfg.n_candidates as f64 * self.cfg.local_frac) as usize;
        let n_global = self.cfg.n_candidates - n_local;
        let mut out = Vec::with_capacity(self.cfg.n_candidates);
        for _ in 0..n_global {
            match &self.cfg.anchors {
                Some(anchors) if !anchors.is_empty() => {
                    let a = &anchors[rng.below(anchors.len())];
                    out.push(
                        a.iter()
                            .map(|&v| {
                                (v + rng.normal() * self.cfg.anchor_sigma).clamp(0.0, 1.0)
                            })
                            .collect(),
                    );
                }
                _ => out.push(space.random_point(rng)),
            }
        }
        // Local exploitation with two scales: fine steps around the
        // incumbent plus heavy-tailed jumps so single-flag optima far from
        // the incumbent (e.g. CompileThreshold at the low end of its log
        // range) stay reachable within a 20-iteration budget.
        for i in 0..n_local {
            let sigma = if i % 2 == 0 { self.cfg.local_sigma } else { self.cfg.local_sigma * 3.5 };
            let p: Vec<f64> = best
                .iter()
                .map(|&b| (b + rng.normal() * sigma).clamp(0.0, 1.0))
                .collect();
            out.push(p);
        }
        out
    }
}

impl Tuner for BoTuner {
    fn name(&self) -> String {
        if self.warm.is_some() {
            "bo_warm".into()
        } else {
            "bo".into()
        }
    }

    fn tune_ctl(
        &mut self,
        space: &TuneSpace,
        objective: &mut dyn Objective,
        iters: usize,
        ctl: &JobControl,
    ) -> Result<TuneResult> {
        let t0 = Instant::now(); // detlint: allow(wall-clock) -- tuning_time_s telemetry; result values are seed-derived
        // Warm-started hypers (a previous job's adapted values) override
        // the default isotropic prior.  Validated *before* the initial
        // design: every init point is a full benchmark evaluation, and
        // both inputs to the checks are already known here — failing
        // after the evals would waste exactly the cost the REST layer's
        // synchronous 400 for the same mistakes was added to avoid.
        let (lengthscales, sigma_n2) = match &self.cfg.hypers.init {
            Some((ls, s2n)) => {
                anyhow::ensure!(
                    ls.len() == space.dim(),
                    "gp_init_hypers has {} length-scales but the tuning space has {} dimensions",
                    ls.len(),
                    space.dim()
                );
                anyhow::ensure!(
                    ls.iter().all(|l| l.is_finite() && *l > 0.0)
                        && s2n.is_finite()
                        && *s2n > 0.0,
                    "gp_init_hypers must be positive and finite"
                );
                // One-shot isotropic backends (XLA) evaluate their AOT
                // artifact on every acquire: unequal per-dimension scales
                // would only fail there, mid-run.
                anyhow::ensure!(
                    self.backend.supports_hyper_adaptation()
                        || crate::native::ops::iso_lengthscale(ls).is_some(),
                    "gp_init_hypers with unequal length-scales requires a backend with an \
                     ARD-capable surrogate (this backend serves an isotropic one-shot session)"
                );
                (ls.clone(), *s2n)
            }
            None => {
                let ls =
                    self.cfg.hypers.lengthscale_per_sqrt_dim * (space.dim() as f64).sqrt();
                (vec![ls; space.dim()], self.cfg.hypers.sigma_n2)
            }
        };
        // Like the warm-start hypers: validate the batch width before the
        // initial design burns benchmark evaluations on a doomed run (the
        // REST layer 400s the same mistakes synchronously).
        anyhow::ensure!(self.cfg.batch_q >= 1, "batch_q must be at least 1 (got 0)");
        anyhow::ensure!(
            self.cfg.batch_q <= self.cfg.n_candidates,
            "batch_q ({}) cannot exceed the candidate pool size ({})",
            self.cfg.batch_q,
            self.cfg.n_candidates
        );
        anyhow::ensure!(
            self.cfg.batch_q < N_TRAIN,
            "batch_q ({}) cannot reach the GP training budget ({N_TRAIN})",
            self.cfg.batch_q
        );

        let mut rng = Pcg::new(self.cfg.seed);
        let mut xs: Vec<Vec<f64>> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        let mut history = Vec::new();
        // Configs whose measurement failed: never re-proposed.
        let mut quarantine: HashSet<Vec<u64>> = HashSet::new();

        // Per-observation failure flags for the initial design (warm-start
        // rows are historical successes: always empty there, and absent
        // entries read as "succeeded" below).
        let mut init_fail: Vec<bool> = Vec::new();
        match &self.warm {
            Some(warm) => {
                for (x, y) in warm {
                    xs.push(x.clone());
                    ys.push(*y);
                }
            }
            None => {
                // Quasi-random SOBOL exploration (Algorithm 2 input), plus
                // the default configuration as a known starting point.
                let mut init_pts: Vec<Vec<f64>> = Vec::new();
                if self.cfg.include_default {
                    init_pts.push(space.default_point());
                }
                let mut sobol = Sobol::new(space.dim().min(crate::util::sobol::MAX_DIM));
                while init_pts.len() < self.cfg.n_init.max(1) {
                    let mut u = sobol.next_point();
                    pad_init_point(&mut u, space.dim(), self.cfg.seed, init_pts.len() as u64);
                    init_pts.push(u);
                }
                for u in init_pts {
                    let out = objective.eval_outcome(&space.to_config(&u));
                    if out.failure.is_some() {
                        quarantine.insert(unit_key(&u));
                    }
                    history.push(out.y);
                    init_fail.push(out.failure.is_some());
                    xs.push(u);
                    ys.push(out.y);
                }
                // Failed init measurements get the same worst-observed
                // penalty the iteration loop applies, computed once after
                // the sweep completes (deterministic order): the raw
                // garbage reading stays in `history` for telemetry but
                // must never reach the surrogate or the incumbent.
                if init_fail.contains(&true) {
                    let penalty = ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                    for (y, &failed) in ys.iter_mut().zip(&init_fail) {
                        if failed {
                            *y = penalty;
                        }
                    }
                }
            }
        }
        anyhow::ensure!(!xs.is_empty(), "BO needs initial data");
        ctl.note_failures(objective.failures().total());

        // Incumbent over *successful* observations only (first minimum on
        // ties, like `argmin`); an all-failed init falls back to the
        // penalized argmin so the loop still has a point to search around.
        let best_i = {
            let mut b: Option<usize> = None;
            for i in 0..ys.len() {
                if init_fail.get(i).copied().unwrap_or(false) {
                    continue;
                }
                b = match b {
                    Some(j) if ys[j] <= ys[i] => Some(j),
                    _ => Some(i),
                };
            }
            b.unwrap_or_else(|| crate::util::stats::argmin(&ys))
        };
        let mut best_x = xs[best_i].clone();
        let mut best_y = ys[best_i];
        // Running incumbent per init observation: failures carry the
        // previous best forward; while nothing has succeeded yet the
        // penalized running minimum stands in (finite, like the all-failed
        // incumbent fallback above).  Fault-free this is exactly the old
        // running minimum over `history`.
        let mut best_history: Vec<f64> = Vec::with_capacity(history.len());
        {
            let mut b = f64::INFINITY;
            let mut bp = f64::INFINITY;
            for i in 0..history.len() {
                bp = bp.min(ys[i]);
                if !init_fail.get(i).copied().unwrap_or(false) {
                    b = b.min(history[i]);
                }
                best_history.push(if b.is_finite() { b } else { bp });
            }
        }

        // Surrogate session: initial data is fed once, then each
        // iteration appends one observation instead of refitting.
        let gpcfg = GpConfig {
            dim: space.dim(),
            lengthscales,
            sigma_f2: self.cfg.hypers.sigma_f2,
            sigma_n2,
            // An oversized initial design (n_init > N_TRAIN) is allowed,
            // exactly as the pre-session code was: the loop below still
            // evicts one worst point per iteration while over N_TRAIN.
            cap: N_TRAIN.max(xs.len()),
            hyper: self.cfg.hypers.mode,
            ard: self.cfg.hypers.ard,
            kernels: self.cfg.hypers.kernels,
        };
        let backend = std::sync::Arc::clone(&self.backend);
        let mut gp = match self.cfg.surrogate {
            SurrogateMode::Session => backend.gp_open(&gpcfg)?,
            SurrogateMode::OneShot => crate::runtime::one_shot_gp(backend.as_ref(), &gpcfg),
        };
        for (x, &y) in xs.iter().zip(&ys) {
            gp.observe(x, y)?;
        }
        drop((xs, ys));

        let q = self.cfg.batch_q;
        for it in 0..iters {
            // Cooperative stop at the iteration boundary — explicit
            // cancellation or an exhausted failure budget (degraded job):
            // keep everything observed so far and return the best-so-far
            // result below.
            if ctl.should_stop() {
                break;
            }
            if q == 1 {
                // Single-point path, byte-for-byte the pre-batch loop
                // (same rng consumption, same acquire count): batch_q = 1
                // stays bitwise identical to the legacy tuner
                // (`tests/gp_incremental.rs`).
                //
                // Cap the GP training set at the artifact budget: drop the
                // worst old point (kernel-cache eviction + factor rebuild).
                if gp.len() >= N_TRAIN {
                    gp.forget(argmax(gp.ys()))?;
                }
                let cands = self.candidates(space, &best_x, &mut rng);
                let (ei, mu, _) = gp.acquire(&self.cfg.epool, &cands, best_y)?;
                let pick =
                    pick_candidate(&cands, &ei, &mu, self.cfg.safe_baseline, &quarantine);
                let x_next = cands[pick].clone();
                let out = objective.eval_outcome(&space.to_config(&x_next));
                let y_next = out.y;
                history.push(y_next);
                let y_gp = if out.failure.is_some() {
                    // Quarantine the config and feed the surrogate a penalized
                    // value: at least as bad as everything observed, so the GP
                    // learns to avoid the region without swallowing the raw
                    // garbage magnitude of a failed measurement.
                    quarantine.insert(unit_key(&x_next));
                    gp.ys().iter().cloned().fold(y_next, f64::max)
                } else {
                    if y_next < best_y {
                        best_y = y_next;
                        best_x = x_next.clone();
                    }
                    y_next
                };
                best_history.push(best_y);
                gp.observe(&x_next, y_gp)?;
                ctl.note_failures(objective.failures().total());
                ctl.update(|p| {
                    p.iteration = Some(it + 1);
                    p.iters = Some(iters);
                    p.runs_executed = Some(objective.evals());
                    p.best_y = Some(best_y);
                    p.failures = Some(objective.failures());
                });
                continue;
            }
            // q-EI constant-liar batch: make room for the q appends this
            // round will commit (fantasies peak at q-1 extra rows, the
            // real observations at q), then pick q points sequentially
            // against fantasized models.
            while gp.len() > 1 && (gp.len() >= N_TRAIN || gp.len() + q > gpcfg.cap) {
                gp.forget(argmax(gp.ys()))?;
            }
            ctl.update(|p| p.runs_in_flight = Some(q));
            let mut picks: Vec<Vec<f64>> = Vec::with_capacity(q);
            for pi in 0..q {
                let cands = self.candidates(space, &best_x, &mut rng);
                let (ei, mu, _) = gp.acquire(&self.cfg.epool, &cands, best_y)?;
                let pick =
                    pick_candidate(&cands, &ei, &mu, self.cfg.safe_baseline, &quarantine);
                let x_pick = cands[pick].clone();
                if pi + 1 < q {
                    // Constant liar: pretend the pick came back at the
                    // worst target observed so far, so the next pick's EI
                    // collapses around it without inventing optimism.
                    let liar = gp.ys().iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                    gp.fantasize(&x_pick, liar)?;
                }
                picks.push(x_pick);
            }
            // Retract every fantasy before the real measurements: the
            // session is bit-for-bit back where the round started.
            for _ in 0..q - 1 {
                gp.pop_fantasy()?;
            }
            let cfgs: Vec<crate::flags::FlagConfig> =
                picks.iter().map(|u| space.to_config(u)).collect();
            let outs = objective.eval_outcomes_batch(&cfgs);
            // Observe all q outcomes, in pick order, before the next
            // acquisition round — failures individually quarantined and
            // penalized exactly like the single-point path.
            for (x_next, out) in picks.into_iter().zip(outs) {
                let y_next = out.y;
                history.push(y_next);
                let y_gp = if out.failure.is_some() {
                    quarantine.insert(unit_key(&x_next));
                    gp.ys().iter().cloned().fold(y_next, f64::max)
                } else {
                    if y_next < best_y {
                        best_y = y_next;
                        best_x = x_next.clone();
                    }
                    y_next
                };
                best_history.push(best_y);
                gp.observe(&x_next, y_gp)?;
            }
            ctl.note_failures(objective.failures().total());
            ctl.update(|p| {
                p.iteration = Some(it + 1);
                p.iters = Some(iters);
                p.runs_executed = Some(objective.evals());
                p.best_y = Some(best_y);
                p.failures = Some(objective.failures());
                p.runs_in_flight = Some(0);
            });
        }

        // Report the surrogate's final hypers (the warm-start payload for
        // a follow-up job) and, after an ARD-adapted run, the normalized
        // per-dimension relevance — the second relevance signal the
        // pipeline cross-checks against the lasso selection.  Relevance
        // is only claimed when the length-scales actually *moved* under an
        // ARD-capable session (native, adaptive policy): a one-shot or
        // non-adaptive surrogate — or an adaptive one whose run was too
        // short for adaptation to fire or accept a step — still has its
        // initial scales, and a uniform 1/d vector from those would be
        // noise dressed up as a learned signal.
        let (final_ls, final_s2n) = gp.hypers();
        let adapted_ard = self.cfg.hypers.ard
            && matches!(self.cfg.hypers.mode, HyperMode::Adapt { .. })
            && matches!(self.cfg.surrogate, SurrogateMode::Session)
            && self.backend.supports_hyper_adaptation()
            && final_ls != gpcfg.lengthscales;
        let ard_relevance =
            if adapted_ard { Some(crate::featsel::ard_relevance(&final_ls)) } else { None };

        Ok(TuneResult {
            algo: self.name(),
            best_config: space.to_config(&best_x),
            best_y,
            history,
            best_history,
            evals: objective.evals(),
            sim_time_s: objective.sim_time_s(),
            algo_wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            gp_hypers: Some((final_ls, final_s2n)),
            ard_relevance,
            failures: objective.failures(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flags::GcMode;
    use crate::runtime::NativeBackend;
    use crate::tuner::objective::EvalOutcome;
    use std::sync::Arc;

    /// Cheap synthetic objective: quadratic bowl in the unit cube with
    /// optimum at 0.7 per dim.
    struct Bowl {
        space: TuneSpace,
        count: usize,
    }

    impl Objective for Bowl {
        fn eval_outcome(&mut self, cfg: &crate::flags::FlagConfig) -> EvalOutcome {
            self.count += 1;
            let u = self.space.project(cfg);
            let y = u.iter().map(|&x| (x - 0.7) * (x - 0.7)).sum();
            EvalOutcome { y, failure: None, attempts: 1 }
        }
        fn evals(&self) -> usize {
            self.count
        }
        fn sim_time_s(&self) -> f64 {
            self.count as f64
        }
    }

    /// Bowl that *fails* (transient crash) whenever the first tuned
    /// dimension exceeds a threshold — the failure region the quarantine
    /// and safe-baseline logic must learn to avoid.
    struct FailingBowl {
        space: TuneSpace,
        count: usize,
        threshold: f64,
        failures: crate::sparksim::FailureHisto,
        evaluated: Vec<Vec<f64>>,
    }

    impl FailingBowl {
        fn new(space: TuneSpace, threshold: f64) -> Self {
            FailingBowl {
                space,
                count: 0,
                threshold,
                failures: Default::default(),
                evaluated: Vec::new(),
            }
        }
    }

    impl Objective for FailingBowl {
        fn eval_outcome(&mut self, cfg: &crate::flags::FlagConfig) -> EvalOutcome {
            self.count += 1;
            let u = self.space.project(cfg);
            self.evaluated.push(u.clone());
            if u[0] > self.threshold {
                self.failures.record(crate::jvmsim::FailureKind::Crash);
                return EvalOutcome {
                    y: 100.0, // penalty magnitude, like a capped exec time
                    failure: Some(crate::jvmsim::FailureKind::Crash),
                    attempts: 2,
                };
            }
            let y = u.iter().map(|&x| (x - 0.3) * (x - 0.3)).sum();
            EvalOutcome { y, failure: None, attempts: 1 }
        }
        fn evals(&self) -> usize {
            self.count
        }
        fn sim_time_s(&self) -> f64 {
            self.count as f64
        }
        fn failures(&self) -> crate::sparksim::FailureHisto {
            self.failures
        }
    }

    fn small_space() -> TuneSpace {
        let mut sp = TuneSpace::full(GcMode::ParallelGC);
        sp.selected.truncate(6);
        sp
    }

    #[test]
    fn bo_improves_over_init() {
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 6,
            n_candidates: 128,
            ..Default::default()
        });
        let r = bo.tune(&space, &mut obj, 12).unwrap();
        let init_best = r.best_history[5];
        assert!(r.best_y <= init_best);
        assert!(r.best_y < 0.35, "best_y={}", r.best_y);
        assert_eq!(r.evals, 6 + 12);
        assert_eq!(r.history.len(), 18);
        assert_eq!(r.best_history.len(), 18);
    }

    #[test]
    fn bo_improves_with_adaptive_surrogate() {
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 6,
            n_candidates: 128,
            hypers: GpHypers { mode: HyperMode::adapt(), ..Default::default() },
            ..Default::default()
        });
        let r = bo.tune(&space, &mut obj, 12).unwrap();
        assert!(r.best_y.is_finite());
        assert!(r.best_y <= r.best_history[5], "adaptation must not lose the init best");
        assert!(r.best_y < 0.5, "best_y={}", r.best_y);
        for w in r.best_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn ard_tune_reports_hypers_and_relevance() {
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 8,
            n_candidates: 128,
            hypers: GpHypers {
                mode: HyperMode::Adapt { every: 4 },
                ard: true,
                // Grossly long initial scales: the ascent must accept at
                // least one step (same construction gp_downdate pins), so
                // the moved-scales gate on relevance reporting opens
                // deterministically.
                init: Some((vec![10.0; 6], 0.01)),
                ..Default::default()
            },
            ..Default::default()
        });
        let r = bo.tune(&space, &mut obj, 10).unwrap();
        let (ls, s2n) = r.gp_hypers.as_ref().expect("BO must report final GP hypers");
        assert_eq!(ls.len(), space.dim());
        assert!(ls.iter().all(|l| l.is_finite() && *l > 0.0));
        assert!(s2n.is_finite() && *s2n > 0.0);
        assert_ne!(ls, &vec![10.0; 6], "adaptation must have moved the scales");
        let rel = r.ard_relevance.as_ref().expect("ARD tune must report relevance");
        assert_eq!(rel.len(), space.dim());
        let sum: f64 = rel.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "relevance must be normalized: {sum}");
    }

    #[test]
    fn ard_without_movement_reports_no_relevance() {
        // Adaptation enabled but the cadence never reached: the scales
        // never move, so the result must not dress a uniform vector up as
        // a learned relevance signal.
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 4,
            n_candidates: 64,
            hypers: GpHypers {
                mode: HyperMode::Adapt { every: usize::MAX },
                ard: true,
                ..Default::default()
            },
            ..Default::default()
        });
        let r = bo.tune(&space, &mut obj, 3).unwrap();
        assert!(r.gp_hypers.is_some());
        assert!(r.ard_relevance.is_none(), "unmoved scales cannot claim relevance");
    }

    #[test]
    fn fixed_tune_reports_hypers_but_no_relevance() {
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 5,
            n_candidates: 64,
            ..Default::default()
        });
        let r = bo.tune(&space, &mut obj, 4).unwrap();
        assert!(r.gp_hypers.is_some());
        assert!(r.ard_relevance.is_none(), "fixed hypers cannot claim ARD relevance");
    }

    #[test]
    fn init_hypers_wrong_dimension_errors() {
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 4,
            n_candidates: 64,
            hypers: GpHypers {
                init: Some((vec![0.5; 2], 0.01)), // space has 6 dims
                ..Default::default()
            },
            ..Default::default()
        });
        let err = bo.tune(&space, &mut obj, 3).unwrap_err().to_string();
        assert!(err.contains("length-scales"), "{err}");
        // Validation fires before the initial design: no benchmark
        // evaluation may be burned on a doomed run.
        assert_eq!(obj.evals(), 0, "init evals ran before validation");
    }

    #[test]
    fn init_hypers_round_trip_seeds_next_session() {
        let space = small_space();
        // First tune adapts; its reported hypers seed a second tune whose
        // session must start exactly there (Fixed: they never move).
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut first = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 8,
            n_candidates: 64,
            hypers: GpHypers { mode: HyperMode::Adapt { every: 4 }, ..Default::default() },
            ..Default::default()
        });
        let r1 = first.tune(&space, &mut obj, 6).unwrap();
        let warm = r1.gp_hypers.clone().unwrap();

        let mut obj2 = Bowl { space: space.clone(), count: 0 };
        let mut second = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 4,
            n_candidates: 64,
            hypers: GpHypers { init: Some(warm.clone()), ..Default::default() },
            ..Default::default()
        });
        let r2 = second.tune(&space, &mut obj2, 3).unwrap();
        let got = r2.gp_hypers.unwrap();
        assert_eq!(got.0, warm.0, "fixed session must keep the warm-started scales");
        assert_eq!(got.1, warm.1);
    }

    #[test]
    fn best_history_monotone() {
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 4,
            n_candidates: 64,
            ..Default::default()
        });
        let r = bo.tune(&space, &mut obj, 8).unwrap();
        for w in r.best_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
        // best_y consistent with history
        let min_hist = r.history.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((r.best_y - min_hist).abs() < 1e-12);
    }

    #[test]
    fn pre_cancelled_tune_returns_init_best_without_iterating() {
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 5,
            n_candidates: 64,
            ..Default::default()
        });
        let ctl = JobControl::default();
        ctl.cancel();
        let r = bo.tune_ctl(&space, &mut obj, 12, &ctl).unwrap();
        // Only the init design ran; the best-so-far partial result stands.
        assert_eq!(r.evals, 5, "cancelled loop must not consume iterations");
        assert_eq!(r.history.len(), 5);
        let min_init = r.history.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((r.best_y - min_init).abs() < 1e-12);
    }

    #[test]
    fn tune_ctl_publishes_monotone_iteration_progress() {
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 4,
            n_candidates: 64,
            ..Default::default()
        });
        let ctl = JobControl::default();
        bo.tune_ctl(&space, &mut obj, 7, &ctl).unwrap();
        let p = ctl.progress();
        assert_eq!(p.iteration, Some(7));
        assert_eq!(p.iters, Some(7));
        assert_eq!(p.runs_executed, Some(4 + 7));
        assert!(p.best_y.unwrap().is_finite());
    }

    #[test]
    fn warm_start_uses_no_init_evals() {
        let space = small_space();
        // Fake AL dataset: points near the optimum with their true values.
        let mut rng = Pcg::new(3);
        let mut unit_rows = Vec::new();
        let mut y = Vec::new();
        let enc = crate::flags::FeatureEncoder::new(GcMode::ParallelGC);
        for _ in 0..30 {
            let cfg = crate::flags::FlagConfig::random(GcMode::ParallelGC, &mut rng);
            let u_full = cfg.to_unit();
            let u = space.project_unit(&u_full);
            y.push(u.iter().map(|&x| (x - 0.7) * (x - 0.7)).sum());
            unit_rows.push(u_full);
        }
        let ds = crate::datagen::Dataset {
            mode: GcMode::ParallelGC,
            metric: crate::Metric::ExecTime,
            feat_rows: unit_rows
                .iter()
                .map(|u| enc.encode(&crate::flags::FlagConfig::from_unit(GcMode::ParallelGC, u)))
                .collect(),
            unit_rows,
            y,
        };
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::warm_start(
            Arc::new(NativeBackend),
            BoConfig { n_candidates: 128, ..Default::default() },
            &space,
            &ds,
        );
        let r = bo.tune(&space, &mut obj, 10).unwrap();
        assert_eq!(r.algo, "bo_warm");
        assert_eq!(r.evals, 10, "warm start must not burn init evals");
        assert!(r.best_y < 0.5);
    }

    #[test]
    fn failed_configs_are_quarantined_not_reproposed() {
        let space = small_space();
        let mut obj = FailingBowl::new(space.clone(), 0.8);
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 6,
            n_candidates: 64,
            ..Default::default()
        });
        let r = bo.tune(&space, &mut obj, 15).unwrap();
        assert_eq!(r.evals, 6 + 15);
        assert_eq!(
            r.failures.crash,
            obj.evaluated.iter().filter(|u| u[0] > 0.8).count(),
            "result histogram must match what actually failed"
        );
        // No failed point may ever be proposed twice (bitwise identity).
        let failed: Vec<&Vec<f64>> =
            obj.evaluated.iter().filter(|u| u[0] > 0.8).collect();
        for (a, fa) in failed.iter().enumerate() {
            for fb in failed.iter().skip(a + 1) {
                assert_ne!(fa, fb, "a quarantined config was re-proposed");
            }
        }
        // The winner must come from the feasible region.
        let best_u = space.project(&r.best_config);
        assert!(best_u[0] <= 0.8, "best config sits in the failure region");
    }

    /// Objective whose *first* evaluation — an init-design point — fails
    /// with a garbage-LOW reading (a crashed measurement can report
    /// anything).  Successful evals are the 0.7-bowl, so every honest
    /// value is >= 0.
    struct PoisonFirstBowl {
        space: TuneSpace,
        count: usize,
        failures: crate::sparksim::FailureHisto,
    }

    impl Objective for PoisonFirstBowl {
        fn eval_outcome(&mut self, cfg: &crate::flags::FlagConfig) -> EvalOutcome {
            self.count += 1;
            if self.count == 1 {
                self.failures.record(crate::jvmsim::FailureKind::Crash);
                return EvalOutcome {
                    y: -1000.0, // garbage-low: below every honest value
                    failure: Some(crate::jvmsim::FailureKind::Crash),
                    attempts: 2,
                };
            }
            let u = self.space.project(cfg);
            let y = u.iter().map(|&x| (x - 0.7) * (x - 0.7)).sum();
            EvalOutcome { y, failure: None, attempts: 1 }
        }
        fn evals(&self) -> usize {
            self.count
        }
        fn sim_time_s(&self) -> f64 {
            self.count as f64
        }
        fn failures(&self) -> crate::sparksim::FailureHisto {
            self.failures
        }
    }

    /// The headline regression: a failed init observation used to be fed
    /// to the GP raw AND win the argmin, seeding `best_y` with garbage
    /// and deflating EI everywhere.  Post-fix the incumbent comes from
    /// successful runs only and the trajectory never dips below an
    /// honest value.  (Fails on the pre-fix code: best_y was -1000.)
    #[test]
    fn failed_init_observation_cannot_become_incumbent() {
        let space = small_space();
        let mut obj =
            PoisonFirstBowl { space: space.clone(), count: 0, failures: Default::default() };
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 6,
            n_candidates: 64,
            ..Default::default()
        });
        let r = bo.tune(&space, &mut obj, 5).unwrap();
        assert_eq!(r.failures.crash, 1);
        assert!(
            r.best_y >= 0.0,
            "garbage-low failed reading became the incumbent: {}",
            r.best_y
        );
        assert!(
            r.best_history.iter().all(|&b| b >= 0.0),
            "best_history dipped to the failed reading: {:?}",
            r.best_history
        );
        // The raw reading stays visible in telemetry.
        assert!(r.history.contains(&-1000.0));
    }

    /// All-failed init design: the penalized fallback incumbent keeps the
    /// loop (and its trajectory) finite instead of poisoned or infinite.
    #[test]
    fn all_failed_init_keeps_finite_incumbent() {
        let space = small_space();
        let mut obj = FailingBowl::new(space.clone(), -1.0); // everything fails
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 4,
            n_candidates: 64,
            ..Default::default()
        });
        let r = bo.tune(&space, &mut obj, 2).unwrap();
        assert!(r.best_y.is_finite());
        assert!(r.best_history.iter().all(|b| b.is_finite()));
    }

    /// Records every projected evaluation so the padded init coordinates
    /// are observable from outside the tuner.
    struct Recorder {
        space: TuneSpace,
        count: usize,
        seen: Vec<Vec<f64>>,
    }

    impl Objective for Recorder {
        fn eval_outcome(&mut self, cfg: &crate::flags::FlagConfig) -> EvalOutcome {
            self.count += 1;
            let u = self.space.project(cfg);
            let y = u.iter().take(4).map(|&x| (x - 0.5) * (x - 0.5)).sum();
            self.seen.push(u);
            EvalOutcome { y, failure: None, attempts: 1 }
        }
        fn evals(&self) -> usize {
            self.count
        }
        fn sim_time_s(&self) -> f64 {
            self.count as f64
        }
    }

    /// Dimensions past the Sobol generator's MAX_DIM used to be frozen at
    /// 0.5 in every init point (duplicated kernel columns, zero
    /// exploration there).  The padded coordinates must be distinct
    /// across init points, in-range, reproducible, and a strict no-op
    /// for spaces within the generator's reach.
    #[test]
    fn sobol_padding_is_seeded_per_point_not_frozen() {
        let dim = crate::util::sobol::MAX_DIM + 5;
        let pad_of = |point_index: u64| -> Vec<f64> {
            let mut u = vec![0.25; crate::util::sobol::MAX_DIM];
            pad_init_point(&mut u, dim, 0xb0, point_index);
            assert_eq!(u.len(), dim);
            u.split_off(crate::util::sobol::MAX_DIM)
        };
        let pads: Vec<Vec<f64>> = (0..4u64).map(pad_of).collect();
        for (i, a) in pads.iter().enumerate() {
            assert!(a.iter().all(|v| (0.0..1.0).contains(v)));
            assert!(a.iter().all(|&v| v != 0.5), "frozen 0.5 padding survived");
            assert!(a.windows(2).any(|w| w[0] != w[1]), "constant padding stream");
            for b in &pads[i + 1..] {
                assert_ne!(a, b, "points {i}+ share a padding stream");
            }
        }
        assert_eq!(pads, (0..4u64).map(pad_of).collect::<Vec<_>>(), "must be reproducible");
        // Within the generator's reach nothing is touched.
        let mut full = vec![0.25; 8];
        pad_init_point(&mut full, 8, 0xb0, 3);
        assert_eq!(full, vec![0.25; 8]);
    }

    /// End-to-end over a space wider than MAX_DIM: the tuner runs, and
    /// two identical runs are bitwise equal (the padding streams are
    /// seeded, not ambient).
    #[test]
    fn tune_past_max_dim_is_reproducible() {
        let mut sp = TuneSpace::full(GcMode::G1GC);
        let base = sp.selected.clone();
        while sp.selected.len() <= crate::util::sobol::MAX_DIM + 4 {
            let next = base[sp.selected.len() % base.len()];
            sp.selected.push(next);
        }
        assert!(sp.dim() > crate::util::sobol::MAX_DIM);
        let run = || {
            let mut obj = Recorder { space: sp.clone(), count: 0, seen: Vec::new() };
            let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
                n_init: 5,
                n_candidates: 32,
                ..Default::default()
            });
            let r = bo.tune(&sp, &mut obj, 2).unwrap();
            (r, obj.seen)
        };
        let (r1, seen1) = run();
        assert_eq!(r1.evals, 5 + 2);
        let (r2, seen2) = run();
        assert_eq!(seen1, seen2, "padded init design must be reproducible");
        assert_eq!(r1.best_y.to_bits(), r2.best_y.to_bits());
    }

    #[test]
    fn batch_q_zero_or_oversized_errors_before_any_eval() {
        let space = small_space();
        for (q, ncand) in [(0usize, 64usize), (65, 64)] {
            let mut obj = Bowl { space: space.clone(), count: 0 };
            let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
                n_init: 4,
                n_candidates: ncand,
                batch_q: q,
                ..Default::default()
            });
            let err = bo.tune(&space, &mut obj, 3).unwrap_err().to_string();
            assert!(err.contains("batch_q"), "{err}");
            assert_eq!(obj.evals(), 0, "validation must fire before the init design");
        }
    }

    #[test]
    fn batch_tune_runs_q_evals_per_iteration_and_improves() {
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 6,
            n_candidates: 128,
            batch_q: 3,
            ..Default::default()
        });
        let r = bo.tune(&space, &mut obj, 6).unwrap();
        assert_eq!(r.evals, 6 + 3 * 6, "q configs must be measured per iteration");
        assert_eq!(r.history.len(), 6 + 18);
        assert_eq!(r.best_history.len(), 6 + 18);
        assert!(r.best_y <= r.best_history[5]);
        assert!(r.best_y < 0.35, "best_y={}", r.best_y);
        for w in r.best_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn batch_tune_quarantines_failures_individually() {
        let space = small_space();
        let mut obj = FailingBowl::new(space.clone(), 0.8);
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 6,
            n_candidates: 64,
            batch_q: 4,
            ..Default::default()
        });
        let r = bo.tune(&space, &mut obj, 8).unwrap();
        assert_eq!(r.evals, 6 + 4 * 8);
        assert_eq!(
            r.failures.crash,
            obj.evaluated.iter().filter(|u| u[0] > 0.8).count(),
            "every in-batch failure must reach the histogram"
        );
        let best_u = space.project(&r.best_config);
        assert!(best_u[0] <= 0.8, "best config sits in the failure region");
    }

    #[test]
    fn exhausted_fail_budget_degrades_the_run() {
        let space = small_space();
        // Fail everything: every init point trips the budget immediately.
        let mut obj = FailingBowl::new(space.clone(), -1.0);
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 5,
            n_candidates: 64,
            ..Default::default()
        });
        let ctl = JobControl::default();
        ctl.set_fail_budget(2);
        let r = bo.tune_ctl(&space, &mut obj, 12, &ctl).unwrap();
        assert!(ctl.is_degraded(), "budget of 2 with 5 failing init evals must degrade");
        assert!(!ctl.is_cancelled());
        assert_eq!(r.evals, 5, "degraded loop must stop at the first boundary");
        assert_eq!(r.failures.crash, 5);
    }

    #[test]
    fn safe_baseline_fallback_keeps_the_loop_alive() {
        // An impossibly low baseline rejects every candidate by predicted
        // mean; the fallback must keep proposing (plain EI) instead of
        // wedging, and eval counts stay exactly as configured.
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
            n_init: 5,
            n_candidates: 64,
            safe_baseline: Some(f64::NEG_INFINITY),
            ..Default::default()
        });
        let r = bo.tune(&space, &mut obj, 6).unwrap();
        assert_eq!(r.evals, 5 + 6);
        assert!(r.best_y.is_finite());
    }

    #[test]
    fn safe_baseline_none_is_bitwise_transparent() {
        let space = small_space();
        let run = |baseline: Option<f64>| {
            let mut obj = Bowl { space: space.clone(), count: 0 };
            let mut bo = BoTuner::new(Arc::new(NativeBackend), BoConfig {
                n_init: 6,
                n_candidates: 128,
                safe_baseline: baseline,
                ..Default::default()
            });
            bo.tune(&space, &mut obj, 8).unwrap()
        };
        let plain = run(None);
        // A baseline far above every observable value never rejects, so
        // the guarded pick must reduce to the same argmax-EI choice.
        let guarded = run(Some(f64::INFINITY));
        assert_eq!(plain.history, guarded.history);
        assert_eq!(plain.best_y, guarded.best_y);
    }
}
