//! Simulated Annealing baseline with Latin-Hypercube start (paper §IV-E:
//! "We used Latin Hypercube sampling (LHS) of SA ... empirically proven to
//! be useful in cutting down processing time").

use std::time::Instant;

use anyhow::Result;

use super::objective::Objective;
use super::space::TuneSpace;
use super::{TuneResult, Tuner};
use crate::exec::JobControl;
use crate::util::lhs::lhs;
use crate::util::rng::Pcg;

#[derive(Clone, Debug)]
pub struct SaConfig {
    /// Latin-hypercube initial samples.
    pub n_init: usize,
    /// Initial temperature (relative to the spread of the init values).
    pub t0: f64,
    /// Geometric cooling factor per iteration.
    pub cooling: f64,
    /// Per-dimension mutation probability.
    pub mut_prob: f64,
    /// Mutation scale (fraction of the unit range at T = t0).
    pub mut_sigma: f64,
    pub seed: u64,
}

impl Default for SaConfig {
    fn default() -> Self {
        SaConfig {
            n_init: 5,
            t0: 0.6,
            cooling: 0.85,
            mut_prob: 0.25,
            mut_sigma: 0.20,
            seed: 0x5a,
        }
    }
}

pub struct SaTuner {
    pub cfg: SaConfig,
}

impl SaTuner {
    pub fn new(cfg: SaConfig) -> Self {
        SaTuner { cfg }
    }
}

impl Tuner for SaTuner {
    fn name(&self) -> String {
        "sa".into()
    }

    fn tune_ctl(
        &mut self,
        space: &TuneSpace,
        objective: &mut dyn Objective,
        iters: usize,
        ctl: &JobControl,
    ) -> Result<TuneResult> {
        let t0 = Instant::now(); // detlint: allow(wall-clock) -- tuning_time_s telemetry; result values are seed-derived
        let mut rng = Pcg::new(self.cfg.seed);
        let mut history = Vec::new();
        let mut best_history = Vec::new();

        // LHS exploration phase, anchored by the default configuration
        // (the operator always knows the untuned starting point).
        let mut init = vec![space.default_point()];
        init.extend(lhs(&mut rng, self.cfg.n_init.max(2) - 1, space.dim()));
        let mut cur_x = Vec::new();
        let mut cur_y = f64::INFINITY;
        let mut best_x = Vec::new();
        let mut best_y = f64::INFINITY;
        let mut init_vals = Vec::new();
        for p in init {
            let out = objective.eval_outcome(&space.to_config(&p));
            let y = out.y;
            history.push(y);
            init_vals.push(y);
            // A failed measurement only contributes its penalty value to
            // the temperature scale — it can never become the incumbent.
            if out.failure.is_none() {
                if y < cur_y {
                    cur_y = y;
                    cur_x = p.clone();
                }
                if y < best_y {
                    best_y = y;
                    best_x = p;
                }
            }
            best_history.push(best_y);
        }
        ctl.note_failures(objective.failures().total());
        // Degenerate start (every init point failed): anchor the walk at
        // the default config so the proposal loop has a current point.
        if cur_x.is_empty() {
            cur_x = space.default_point();
            best_x = cur_x.clone();
        }

        // Temperature scale from the observed spread so acceptance is
        // meaningful in the metric's units.
        let spread = crate::util::stats::summarize(&init_vals).std.max(best_y.abs() * 0.02).max(1e-9);
        let mut temp = self.cfg.t0;

        for it in 0..iters {
            // Stopped (cancelled or failure budget exhausted): return the
            // best-so-far partial result.
            if ctl.should_stop() {
                break;
            }
            // Propose a neighbour.
            let sigma = self.cfg.mut_sigma * (temp / self.cfg.t0).max(0.05);
            let mut prop = cur_x.clone();
            let mut changed = false;
            for v in prop.iter_mut() {
                if rng.f64() < self.cfg.mut_prob {
                    *v = (*v + rng.normal() * sigma).clamp(0.0, 1.0);
                    changed = true;
                }
            }
            if !changed {
                let j = rng.below(prop.len());
                prop[j] = (prop[j] + rng.normal() * sigma).clamp(0.0, 1.0);
            }

            let out = objective.eval_outcome(&space.to_config(&prop));
            let y = out.y;
            history.push(y);
            // A failed proposal is never accepted as the walk's current
            // point and never the best — but it still burns an iteration
            // (and cools the temperature), like a wasted real run would.
            if out.failure.is_none() {
                let accept = y < cur_y || {
                    let d = (y - cur_y) / spread;
                    rng.f64() < (-d / temp.max(1e-9)).exp()
                };
                if accept {
                    cur_x = prop.clone();
                    cur_y = y;
                }
                if y < best_y {
                    best_y = y;
                    best_x = prop;
                }
            }
            best_history.push(best_y);
            temp *= self.cfg.cooling;
            ctl.note_failures(objective.failures().total());
            ctl.update(|p| {
                p.iteration = Some(it + 1);
                p.iters = Some(iters);
                p.runs_executed = Some(objective.evals());
                p.best_y = Some(best_y);
                p.failures = Some(objective.failures());
            });
        }

        Ok(TuneResult {
            algo: self.name(),
            best_config: space.to_config(&best_x),
            best_y,
            history,
            best_history,
            evals: objective.evals(),
            sim_time_s: objective.sim_time_s(),
            algo_wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            // SA has no GP surrogate: no hypers to warm-start, no
            // relevance to report.
            gp_hypers: None,
            ard_relevance: None,
            failures: objective.failures(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flags::GcMode;

    struct Bowl {
        space: TuneSpace,
        count: usize,
    }

    impl Objective for Bowl {
        fn eval_outcome(
            &mut self,
            cfg: &crate::flags::FlagConfig,
        ) -> crate::tuner::objective::EvalOutcome {
            self.count += 1;
            let u = self.space.project(cfg);
            let y = u.iter().map(|&x| (x - 0.3) * (x - 0.3)).sum();
            crate::tuner::objective::EvalOutcome { y, failure: None, attempts: 1 }
        }
        fn evals(&self) -> usize {
            self.count
        }
        fn sim_time_s(&self) -> f64 {
            self.count as f64 * 2.0
        }
    }

    fn small_space() -> TuneSpace {
        let mut sp = TuneSpace::full(GcMode::G1GC);
        sp.selected.truncate(5);
        sp
    }

    #[test]
    fn sa_descends_on_bowl() {
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut sa = SaTuner::new(SaConfig::default());
        let r = sa.tune(&space, &mut obj, 25).unwrap();
        assert!(r.best_y < 0.3, "best={}", r.best_y);
        assert_eq!(r.evals, 5 + 25);
        assert_eq!(r.history.len(), 30);
        // init includes the default point
        assert!(r.history.len() >= 5);
    }

    #[test]
    fn best_history_monotone_nonincreasing() {
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut sa = SaTuner::new(SaConfig::default());
        let r = sa.tune(&space, &mut obj, 15).unwrap();
        for w in r.best_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn cancellation_keeps_best_so_far() {
        let space = small_space();
        let mut obj = Bowl { space: space.clone(), count: 0 };
        let mut sa = SaTuner::new(SaConfig::default());
        let ctl = JobControl::default();
        ctl.cancel();
        let r = sa.tune_ctl(&space, &mut obj, 25, &ctl).unwrap();
        // Only the LHS init ran; best-so-far is the init minimum.
        assert_eq!(r.evals, 5);
        let min_init = r.history.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((r.best_y - min_init).abs() < 1e-12);
        assert_eq!(ctl.progress().iteration, None, "no iteration completed");
    }

    #[test]
    fn deterministic_given_seed() {
        let space = small_space();
        let run = || {
            let mut obj = Bowl { space: space.clone(), count: 0 };
            let mut sa = SaTuner::new(SaConfig { seed: 77, ..Default::default() });
            sa.tune(&space, &mut obj, 10).unwrap().best_y
        };
        assert_eq!(run(), run());
    }
}
