//! Phase 2 — feature selection by lasso regression (paper §III-C, eq. 6):
//! standardize the phase-1 features, fit lasso through the `lasso_fit` HLO
//! artifact, and keep only flags with non-zero weight.  λ defaults to the
//! paper's grid-searched 0.01 (§IV-C); `grid_search_lambda` reproduces that
//! search.

use std::sync::Arc;

use anyhow::Result;

use crate::datagen::Dataset;
use crate::flags::FeatureEncoder;
use crate::runtime::MlBackend;
use crate::util::stats::{Standardizer, TargetScaler};

/// Weight threshold below which a feature counts as dropped.
pub const SELECT_TOL: f64 = 1e-4;

/// The paper's λ (§IV-C, found by grid search).
pub const DEFAULT_LAMBDA: f64 = 0.01;

/// Output of feature selection.
#[derive(Clone, Debug)]
pub struct Selection {
    pub lambda: f64,
    /// Per-feature lasso weights (standardized space).
    pub weights: Vec<f64>,
    /// Selected flag positions within the GC group (deduplicated across a
    /// flag's linear and squared features).
    pub selected: Vec<usize>,
    /// Selected flag names, same order as `selected`.
    pub names: Vec<String>,
    /// Flag-group size (Table II denominator: 126 or 141).
    pub group_size: usize,
}

impl Selection {
    pub fn n_selected(&self) -> usize {
        self.selected.len()
    }
}

/// Fit lasso on the dataset and collapse feature weights to selected flags.
pub fn select_flags(
    ds: &Dataset,
    lambda: f64,
    backend: &Arc<dyn MlBackend>,
) -> Result<Selection> {
    anyhow::ensure!(!ds.is_empty(), "cannot select flags from an empty dataset");
    let enc = FeatureEncoder::new(ds.mode);
    let xs = Standardizer::fit(&ds.feat_rows);
    let x = xs.transform(&ds.feat_rows);
    let ysc = TargetScaler::fit(&ds.y);
    let y: Vec<f64> = ds.y.iter().map(|&v| ysc.transform(v)).collect();

    let weights = backend.lasso_fit(&x, &y, lambda)?;
    let selected = enc.selected_flags(&weights, SELECT_TOL);
    let names = selected.iter().map(|&p| enc.flag_name(p).to_string()).collect();
    Ok(Selection {
        lambda,
        weights,
        selected,
        names,
        group_size: enc.n_flags(),
    })
}

/// Grid-search λ by holdout MSE (the paper's "λ = 0.01 using grid search").
/// Returns the winning λ and the full (λ, holdout MSE, flags kept) grid.
pub fn grid_search_lambda(
    ds: &Dataset,
    lambdas: &[f64],
    backend: &Arc<dyn MlBackend>,
) -> Result<(f64, Vec<(f64, f64, usize)>)> {
    anyhow::ensure!(ds.len() >= 10, "need >= 10 rows for a holdout split");
    let enc = FeatureEncoder::new(ds.mode);
    let n_val = (ds.len() / 5).max(2);
    let n_tr = ds.len() - n_val;

    let xs = Standardizer::fit(&ds.feat_rows);
    let x = xs.transform(&ds.feat_rows);
    let ysc = TargetScaler::fit(&ds.y);
    let y: Vec<f64> = ds.y.iter().map(|&v| ysc.transform(v)).collect();

    let (xtr, xval) = x.split_at(n_tr);
    let (ytr, yval) = y.split_at(n_tr);

    let mut grid = Vec::with_capacity(lambdas.len());
    let mut best = (lambdas[0], f64::INFINITY);
    for &lam in lambdas {
        let w = backend.lasso_fit(xtr, ytr, lam)?;
        let mse: f64 = xval
            .iter()
            .zip(yval)
            .map(|(xi, &yi)| {
                let p = crate::native::ops::lr_predict(&w, xi);
                (p - yi) * (p - yi)
            })
            .sum::<f64>()
            / yval.len() as f64;
        let kept = enc.selected_flags(&w, SELECT_TOL).len();
        grid.push((lam, mse, kept));
        if mse < best.1 {
            best = (lam, mse);
        }
    }
    Ok((best.0, grid))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{characterize, DataGenConfig, Strategy};
    use crate::flags::GcMode;
    use crate::runtime::NativeBackend;
    use crate::sparksim::SparkRunner;
    use crate::{Benchmark, Metric};

    fn dataset(mode: GcMode) -> Dataset {
        let runner = SparkRunner::paper_default(Benchmark::DenseKMeans);
        let cfg = DataGenConfig {
            pool_size: 260,
            seed_runs: 30,
            test_runs: 12,
            batch_k: 25,
            max_rounds: 5,
            rmse_rel_tol: 0.0,
            ridge: 1e-3,
            seed: 11,
        };
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        characterize(&runner, mode, Metric::ExecTime, Strategy::Bemcm, &cfg, &backend)
            .unwrap()
            .dataset
    }

    #[test]
    fn selection_prunes_but_keeps_signal() {
        let ds = dataset(GcMode::ParallelGC);
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        let sel = select_flags(&ds, DEFAULT_LAMBDA, &backend).unwrap();
        assert_eq!(sel.group_size, 126);
        assert!(
            sel.n_selected() > 10 && sel.n_selected() < 126,
            "selected {}",
            sel.n_selected()
        );
        // The dominant GC knob must survive selection.
        assert!(
            sel.names.iter().any(|n| n == "MaxHeapSize" || n == "NewRatio"
                || n == "MaxNewSize" || n == "ParallelGCThreads"),
            "no primary heap flag kept: {:?}",
            sel.names
        );
    }

    #[test]
    fn larger_lambda_selects_fewer() {
        let ds = dataset(GcMode::ParallelGC);
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        let a = select_flags(&ds, 0.005, &backend).unwrap();
        let b = select_flags(&ds, 0.15, &backend).unwrap();
        assert!(b.n_selected() <= a.n_selected());
    }

    #[test]
    fn grid_search_returns_member_of_grid() {
        let ds = dataset(GcMode::ParallelGC);
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        let grid = [0.003, 0.01, 0.03, 0.1];
        let (best, rows) = grid_search_lambda(&ds, &grid, &backend).unwrap();
        assert!(grid.contains(&best));
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.1.is_finite()));
    }

    #[test]
    fn empty_dataset_rejected() {
        let ds = Dataset {
            mode: GcMode::G1GC,
            metric: Metric::ExecTime,
            unit_rows: vec![],
            feat_rows: vec![],
            y: vec![],
        };
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        assert!(select_flags(&ds, 0.01, &backend).is_err());
    }
}
