//! Phase 2 — feature selection by lasso regression (paper §III-C, eq. 6):
//! standardize the phase-1 features, fit lasso through the `lasso_fit` HLO
//! artifact, and keep only flags with non-zero weight.  λ defaults to the
//! paper's grid-searched 0.01 (§IV-C); `grid_search_lambda` reproduces that
//! search.

use std::sync::Arc;

use anyhow::Result;

use crate::datagen::Dataset;
use crate::flags::FeatureEncoder;
use crate::runtime::MlBackend;
use crate::util::stats::{Standardizer, TargetScaler};

/// Weight threshold below which a feature counts as dropped.
pub const SELECT_TOL: f64 = 1e-4;

/// The paper's λ (§IV-C, found by grid search).
pub const DEFAULT_LAMBDA: f64 = 0.01;

/// Output of feature selection.
#[derive(Clone, Debug)]
pub struct Selection {
    pub lambda: f64,
    /// Per-feature lasso weights (standardized space).
    pub weights: Vec<f64>,
    /// Selected flag positions within the GC group (deduplicated across a
    /// flag's linear and squared features).
    pub selected: Vec<usize>,
    /// Selected flag names, same order as `selected`.
    pub names: Vec<String>,
    /// Flag-group size (Table II denominator: 126 or 141).
    pub group_size: usize,
}

impl Selection {
    pub fn n_selected(&self) -> usize {
        self.selected.len()
    }
}

/// Fit lasso on the dataset and collapse feature weights to selected flags.
pub fn select_flags(
    ds: &Dataset,
    lambda: f64,
    backend: &Arc<dyn MlBackend>,
) -> Result<Selection> {
    anyhow::ensure!(!ds.is_empty(), "cannot select flags from an empty dataset");
    let enc = FeatureEncoder::new(ds.mode);
    let xs = Standardizer::fit(&ds.feat_rows);
    let x = xs.transform(&ds.feat_rows);
    let ysc = TargetScaler::fit(&ds.y);
    let y: Vec<f64> = ds.y.iter().map(|&v| ysc.transform(v)).collect();

    let weights = backend.lasso_fit(&x, &y, lambda)?;
    let selected = enc.selected_flags(&weights, SELECT_TOL);
    let names = selected.iter().map(|&p| enc.flag_name(p).to_string()).collect();
    Ok(Selection {
        lambda,
        weights,
        selected,
        names,
        group_size: enc.n_flags(),
    })
}

/// Normalized ARD relevance over the tuned dimensions: `1/ℓⱼ²` scaled to
/// sum to 1.  A short adapted length-scale means the kernel varies fast
/// along that flag — the surrogate found it relevant; a long one means
/// the dimension is effectively ignored.  Reported next to [`Selection`]
/// in `TuneResult` and the REST tune job record so the pipeline can
/// cross-check the GP's relevance signal against the lasso's.
pub fn ard_relevance(lengthscales: &[f64]) -> Vec<f64> {
    let inv: Vec<f64> = lengthscales.iter().map(|l| 1.0 / (l * l)).collect();
    let sum: f64 = inv.iter().sum();
    if !sum.is_finite() || sum <= 0.0 {
        return vec![0.0; lengthscales.len()];
    }
    inv.into_iter().map(|v| v / sum).collect()
}

/// Grid-search λ by holdout MSE (the paper's "λ = 0.01 using grid search").
/// Returns the winning λ and the full (λ, holdout MSE, flags kept) grid.
///
/// The scalers are fit on the **training split only**: fitting them on
/// the full dataset before splitting leaks the validation rows'
/// statistics into the very scaling used to score them, which can flip
/// the winning λ (pinned by `leaky_scaling_flips_the_winning_lambda`).
pub fn grid_search_lambda(
    ds: &Dataset,
    lambdas: &[f64],
    backend: &Arc<dyn MlBackend>,
) -> Result<(f64, Vec<(f64, f64, usize)>)> {
    anyhow::ensure!(ds.len() >= 10, "need >= 10 rows for a holdout split");
    anyhow::ensure!(!lambdas.is_empty(), "grid_search_lambda needs a non-empty lambda grid");
    let enc = FeatureEncoder::new(ds.mode);
    let n_val = (ds.len() / 5).max(2);
    let n_tr = ds.len() - n_val;

    let (tr_rows, val_rows) = ds.feat_rows.split_at(n_tr);
    let (tr_y, val_y) = ds.y.split_at(n_tr);
    let xs = Standardizer::fit(tr_rows);
    let xtr = xs.transform(tr_rows);
    let xval = xs.transform(val_rows);
    let ysc = TargetScaler::fit(tr_y);
    let ytr: Vec<f64> = tr_y.iter().map(|&v| ysc.transform(v)).collect();
    let yval: Vec<f64> = val_y.iter().map(|&v| ysc.transform(v)).collect();
    let (xtr, xval, ytr, yval) = (&xtr[..], &xval[..], &ytr[..], &yval[..]);

    let mut grid = Vec::with_capacity(lambdas.len());
    let mut best = (lambdas[0], f64::INFINITY);
    for &lam in lambdas {
        let w = backend.lasso_fit(xtr, ytr, lam)?;
        let mse: f64 = xval
            .iter()
            .zip(yval)
            .map(|(xi, &yi)| {
                let p = crate::native::ops::lr_predict(&w, xi);
                (p - yi) * (p - yi)
            })
            .sum::<f64>()
            / yval.len() as f64;
        let kept = enc.selected_flags(&w, SELECT_TOL).len();
        grid.push((lam, mse, kept));
        if mse < best.1 {
            best = (lam, mse);
        }
    }
    Ok((best.0, grid))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{characterize, DataGenConfig, Strategy};
    use crate::flags::GcMode;
    use crate::runtime::NativeBackend;
    use crate::sparksim::SparkRunner;
    use crate::{Benchmark, Metric};

    fn dataset(mode: GcMode) -> Dataset {
        let runner = SparkRunner::paper_default(Benchmark::DenseKMeans);
        let cfg = DataGenConfig {
            pool_size: 260,
            seed_runs: 30,
            test_runs: 12,
            batch_k: 25,
            max_rounds: 5,
            rmse_rel_tol: 0.0,
            ridge: 1e-3,
            seed: 11,
        };
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        characterize(&runner, mode, Metric::ExecTime, Strategy::Bemcm, &cfg, &backend)
            .unwrap()
            .dataset
    }

    #[test]
    fn selection_prunes_but_keeps_signal() {
        let ds = dataset(GcMode::ParallelGC);
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        let sel = select_flags(&ds, DEFAULT_LAMBDA, &backend).unwrap();
        assert_eq!(sel.group_size, 126);
        assert!(
            sel.n_selected() > 10 && sel.n_selected() < 126,
            "selected {}",
            sel.n_selected()
        );
        // The dominant GC knob must survive selection.
        assert!(
            sel.names.iter().any(|n| n == "MaxHeapSize" || n == "NewRatio"
                || n == "MaxNewSize" || n == "ParallelGCThreads"),
            "no primary heap flag kept: {:?}",
            sel.names
        );
    }

    #[test]
    fn larger_lambda_selects_fewer() {
        let ds = dataset(GcMode::ParallelGC);
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        let a = select_flags(&ds, 0.005, &backend).unwrap();
        let b = select_flags(&ds, 0.15, &backend).unwrap();
        assert!(b.n_selected() <= a.n_selected());
    }

    #[test]
    fn grid_search_returns_member_of_grid() {
        let ds = dataset(GcMode::ParallelGC);
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        let grid = [0.003, 0.01, 0.03, 0.1];
        let (best, rows) = grid_search_lambda(&ds, &grid, &backend).unwrap();
        assert!(grid.contains(&best));
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.1.is_finite()));
    }

    /// Synthetic 10-row dataset engineered so holdout leakage *flips* the
    /// winning λ.  Only feature column 0 is live (everything else is
    /// zero, hence inert under any scaling):
    ///
    /// * training split (8 rows): x = {0,0,0,0,2,2,2,2}, y = x — a clean
    ///   positive linear signal;
    /// * validation split (2 rows): x = 21, y = −4 — far outside the
    ///   training range on both axes.
    ///
    /// With train-only scaling the tiny-λ model extrapolates the positive
    /// slope to the validation point (prediction ≈ +20 vs target −5 in
    /// scaled units, MSE ≈ 625) and the huge-λ zero model wins (MSE 25).
    /// With leaked scaling the validation outliers drag the means/stds so
    /// the *training* correlation turns negative, the tiny-λ model lands
    /// near the validation target (MSE ≈ 0.29 vs 3.33) and tiny λ wins.
    /// Margins are >10x on both sides, so ISTA convergence slack cannot
    /// blur the flip.
    fn leakage_dataset() -> Dataset {
        let enc = FeatureEncoder::new(GcMode::ParallelGC);
        let d = enc.n_features();
        let mut feat_rows = Vec::new();
        let mut y = Vec::new();
        for i in 0..8 {
            let x0 = if i < 4 { 0.0 } else { 2.0 };
            let mut row = vec![0.0; d];
            row[0] = x0;
            feat_rows.push(row);
            y.push(x0);
        }
        for _ in 0..2 {
            let mut row = vec![0.0; d];
            row[0] = 21.0;
            feat_rows.push(row);
            y.push(-4.0);
        }
        Dataset {
            mode: GcMode::ParallelGC,
            metric: Metric::ExecTime,
            unit_rows: vec![vec![0.0; enc.n_flags()]; 10],
            feat_rows,
            y,
        }
    }

    #[test]
    fn leaky_scaling_flips_the_winning_lambda() {
        let ds = leakage_dataset();
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        let lambdas = [0.001, 10.0];

        // Fixed implementation: scalers fit on the training split only.
        let (best, grid) = grid_search_lambda(&ds, &lambdas, &backend).unwrap();
        assert_eq!(grid.len(), 2);
        assert_eq!(best, 10.0, "train-only scaling must reject the extrapolating fit: {grid:?}");

        // The old, leaky scoring (scalers fit on the full dataset before
        // the split), reproduced inline: it picks the other λ.
        let n_tr = ds.len() - 2;
        let xs = Standardizer::fit(&ds.feat_rows);
        let x = xs.transform(&ds.feat_rows);
        let ysc = TargetScaler::fit(&ds.y);
        let yy: Vec<f64> = ds.y.iter().map(|&v| ysc.transform(v)).collect();
        let (xtr, xval) = x.split_at(n_tr);
        let (ytr, yval) = yy.split_at(n_tr);
        let mut leaky_best = (f64::NAN, f64::INFINITY);
        for &lam in &lambdas {
            let w = backend.lasso_fit(xtr, ytr, lam).unwrap();
            let mse: f64 = xval
                .iter()
                .zip(yval)
                .map(|(xi, &yi)| {
                    let p = crate::native::ops::lr_predict(&w, xi);
                    (p - yi) * (p - yi)
                })
                .sum::<f64>()
                / yval.len() as f64;
            if mse < leaky_best.1 {
                leaky_best = (lam, mse);
            }
        }
        assert_eq!(leaky_best.0, 0.001, "leaked scaling rewards the extrapolating fit");
        assert_ne!(best, leaky_best.0, "the leak must flip the winner");
    }

    #[test]
    fn empty_lambda_grid_rejected() {
        let ds = leakage_dataset();
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        let err = grid_search_lambda(&ds, &[], &backend).unwrap_err().to_string();
        assert!(err.contains("non-empty"), "{err}");
    }

    #[test]
    fn ard_relevance_normalizes_and_ranks_short_scales_first() {
        let rel = ard_relevance(&[0.5, 1.0, 2.0]);
        assert!((rel.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(rel[0] > rel[1] && rel[1] > rel[2], "{rel:?}");
        // Degenerate input collapses to zeros instead of NaN.
        assert_eq!(ard_relevance(&[f64::INFINITY, f64::INFINITY]), vec![0.0, 0.0]);
    }

    #[test]
    fn empty_dataset_rejected() {
        let ds = Dataset {
            mode: GcMode::G1GC,
            metric: Metric::ExecTime,
            unit_rows: vec![],
            feat_rows: vec![],
            y: vec![],
        };
        let backend: Arc<dyn MlBackend> = Arc::new(NativeBackend);
        assert!(select_flags(&ds, 0.01, &backend).is_err());
    }
}
